"""Mesh-sharded tick benchmark: the 34k-cell 4-key serving tick split
over a 1-D cell-axis mesh (``MeshDeviceStack`` / ``route="mesh"``).

Headlines (recorded in ``BENCH_mesh.json``):
 * **per-shard scaling** — the BENCH_device.json headline workload
   (16 groups x 1000 blocks, four warm (where, group_by) keys, one
   fused dense launch) re-run as a sharded ``MeshDeviceStack.tick`` at
   1 / 2 / 4 / 8 shards, answers cross-checked against the
   single-device stack every round;
 * **critical-path speedup** — this host exposes the forced-device
   mesh on ``host_cores`` CPU core(s), so the sharded wall clock runs
   the shards' programs SEQUENTIALLY and cannot show the parallel win.
   The modeled metric times the per-shard program honestly instead: a
   single-device stack sized as ONE shard's block run
   (``ceil(B / S)`` blocks, same keys/groups/quota) — the critical
   path of a shard-parallel tick whose only collective is the
   O(groups) stat-row psum.  Both numbers are recorded; the wall
   clock is labelled for what it is;
 * **transfer audit** — the EXACT compiled dense mesh launch of the
   headline tick is captured (``jit.lower``) and its HLO collective
   footprint parsed (``distributed.collective_footprint``): every
   cross-device collective is bounded by the stat-row psum
   (n_rows x 9 elements) — zero per-cell moment bytes cross devices.

Contract: rows print as ``(name, us_per_call, derived)``; ``--smoke``
shrinks sizes for CI; ``--out DIR`` picks where BENCH_mesh.json lands.
"""
from __future__ import annotations

import argparse
import json
import os
import time

# The forced host-device count must be pinned BEFORE jax initializes
# (import time): default to 8 virtual devices unless the caller already
# forced a count via XLA_FLAGS.
_FORCE_FLAG = "--xla_force_host_platform_device_count"
if _FORCE_FLAG not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + f" {_FORCE_FLAG}=8").strip()

import numpy as np

from repro.core.boundaries import make_boundaries
from repro.core.moment_store import (DeviceMomentStore, DeviceStack,
                                     MeshDeviceStack)
from repro.core.types import IslaParams
from repro.launch.mesh import make_cell_mesh

try:
    from ._timing import time_best
except ImportError:          # script mode: python benchmarks/mesh_bench.py
    from _timing import time_best

MU, SIGMA = 100.0, 20.0
PARAMS = IslaParams()

# Acceptance floors for the modeled (critical-path) speedup on the
# full-size tick; the wall clock on a 1-core host is reported, not gated.
MIN_SPEEDUP = {2: 1.6, 4: 2.5}


def _workload(smoke: bool):
    """(n_groups, n_blocks, quota, rounds, shard counts) — full size
    mirrors BENCH_device.json's headline tick (34k cells, 4 keys)."""
    if smoke:
        return 3, 16, 40, 2, (1, 2)
    return 16, 1000, 64, 5, (1, 2, 4, 8)


def _key_specs(n_groups):
    # Four warm keys: plain, WHERE, GROUP BY, WHERE + GROUP BY.
    return [(False, 1), (True, 1), (False, n_groups), (True, n_groups)]


def _make_passes(rng, n_blocks, n_groups, quota, rounds):
    passes = []
    for _ in range(rounds + 1):
        vals = rng.normal(MU, SIGMA, n_blocks * quota)
        gids = rng.integers(0, n_groups, vals.size)
        mask = rng.random(vals.size) < 0.5
        quotas = np.full(n_blocks, quota, dtype=np.int64)
        passes.append((vals, gids, mask, quotas))
    return passes


def _build_stack(n_blocks, n_groups, mesh=None):
    b = make_boundaries(MU, SIGMA, PARAMS)
    sizes = np.full(n_blocks, 10.0 ** 7)
    stores = [DeviceMomentStore.fresh_device(n_blocks, b, MU, sizes,
                                             n_groups=g)
              for _, g in _key_specs(n_groups)]
    return (DeviceStack(stores) if mesh is None
            else MeshDeviceStack(stores, mesh))


def _tick(stack, n_groups, p):
    vals, gids, mask, quotas = p
    key_gids = [gids if g > 1 else None for _, g in _key_specs(n_groups)]
    key_valids = [mask if pred else None
                  for pred, _ in _key_specs(n_groups)]
    return stack.tick(PARAMS, mode="calibrated", values=vals,
                      quotas=quotas, dense=(key_gids, key_valids))


def _time_stack(stack, n_groups, passes):
    """(best us/tick, last tick output) via the shared min-over-rounds
    harness (warm-up/compile on the first pass)."""
    return time_best(lambda p: _tick(stack, n_groups, p), passes)


def _max_rel_rows(out_a, out_b):
    rel = 0.0
    for (_, ra), (_, rb) in zip(out_a, out_b):
        rel = max(rel, float(np.max(
            np.abs(np.asarray(ra) - np.asarray(rb))
            / np.maximum(np.abs(np.asarray(rb)), 1e-9))))
    return rel


def tick_scaling(smoke=False):
    """Sharded tick vs the single-device stack at every shard count:
    wall clock (sequential on this host), modeled critical path (one
    shard's block run on a single device), and row parity."""
    n_groups, n_blocks, quota, rounds, shard_counts = _workload(smoke)
    rng = np.random.default_rng(0)
    passes = _make_passes(rng, n_blocks, n_groups, quota, rounds)

    single = _build_stack(n_blocks, n_groups)
    ref_us, ref_out = _time_stack(single, n_groups, passes)
    cells = single.n_cells

    rows_out = [(f"single_device_tick/c{cells}", ref_us, 1.0)]
    per_shard = {}
    for s in shard_counts:
        msh = _build_stack(n_blocks, n_groups, mesh=make_cell_mesh(s))
        wall_us, out = _time_stack(msh, n_groups, passes)
        rel = _max_rel_rows(out, ref_out)
        if rel > 1e-3:
            raise AssertionError(
                f"mesh tick diverged from single device at S={s}: "
                f"rel={rel}")
        # Critical path: one shard's slice of the block axis on a
        # single device (per-shard samples shrink with the blocks).
        b_local = -(-n_blocks // s)
        model = _build_stack(b_local, n_groups)
        model_passes = [(v[:b_local * quota], g[:b_local * quota],
                         m[:b_local * quota],
                         np.full(b_local, quota, dtype=np.int64))
                        for v, g, m, _ in passes]
        model_us, _ = _time_stack(model, n_groups, model_passes)
        speedup = ref_us / max(model_us, 1e-9)
        per_shard[s] = {
            "wall_us_per_tick": wall_us,
            "critical_path_us_per_tick": model_us,
            "critical_path_speedup": speedup,
            "blocks_per_shard": b_local,
            "row_max_rel_diff": rel,
        }
        rows_out.append((f"mesh_tick_wall/s{s}", wall_us,
                         ref_us / max(wall_us, 1e-9)))
        rows_out.append((f"mesh_tick_critical_path/s{s}", model_us,
                         speedup))
    if not smoke:
        for s, floor in MIN_SPEEDUP.items():
            got = per_shard[s]["critical_path_speedup"]
            if got < floor:
                raise AssertionError(
                    f"critical-path speedup at {s} shards is "
                    f"{got:.2f}x, below the {floor}x floor")
    return rows_out, {
        "n_groups": n_groups, "n_blocks": n_blocks,
        "keys": len(_key_specs(n_groups)), "cells": cells,
        "samples_per_tick": int(n_blocks * quota), "rounds": rounds,
        "host_cores": os.cpu_count(),
        "single_device_us_per_tick": ref_us,
        "shards": {str(s): rep for s, rep in per_shard.items()},
        "aggregation": "min over rounds",
        "note": "wall clock runs every shard's program sequentially on "
                "this host's core(s); critical_path times ONE shard's "
                "block run on a single device — the latency of a "
                "shard-parallel tick up to the O(groups) stat-row psum",
    }


def transfer_audit(smoke=False):
    """Collective footprint of the EXACT headline dense mesh launch:
    capture the jitted fn + operands from a real ``MeshDeviceStack``
    tick, compile, and parse the HLO for cross-device collectives.
    The zero-moment-traffic contract holds iff every entry is bounded
    by the stat-row psum (n_rows x 9 elements)."""
    import jax

    from repro.core import distributed as D

    n_groups, n_blocks, quota, _, shard_counts = _workload(smoke)
    s = shard_counts[-1]
    msh = _build_stack(n_blocks, n_groups, mesh=make_cell_mesh(s))
    rng = np.random.default_rng(1)
    (p,) = _make_passes(rng, n_blocks, n_groups, quota, 0)

    captured = {}
    real_fn = D.mesh_tick_dense_fn

    def capturing(*a, **kw):
        fn = real_fn(*a, **kw)

        def wrapper(*args):
            captured["lowered"] = fn.lower(*args)
            return fn(*args)
        return wrapper

    D.mesh_tick_dense_fn = capturing
    try:
        _tick(msh, n_groups, p)
    finally:
        D.mesh_tick_dense_fn = real_fn
    hlo = captured["lowered"].compile().as_text()
    footprint = D.collective_footprint(hlo)
    n_rows = sum(g for _, g in _key_specs(n_groups))
    cap = n_rows * 9
    if not footprint:
        raise AssertionError("expected at least the stat-row psum")
    worst = max(elements for _, elements in footprint)
    if worst > cap:
        raise AssertionError(
            f"collective moves {worst} elements, above the "
            f"{cap}-element stat-row cap: {footprint}")
    per_cell_elements = msh.n_cells_mesh * 4  # one moment region's rows
    rows = [(f"mesh_tick_collectives/s{s}", 0.0, float(len(footprint))),
            ("largest_collective_elements", 0.0, float(worst))]
    return rows, {
        "shards": s,
        "collectives": [[op, int(n)] for op, n in footprint],
        "stat_row_cap_elements": cap,
        "largest_collective_elements": int(worst),
        "per_cell_moment_elements_resident": int(per_cell_elements),
        "per_cell_moment_bytes_crossing": 0,
        "audit": "compiled-HLO collective footprint of the captured "
                 "dense mesh launch (distributed.collective_footprint)",
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes so CI can keep the entrypoints alive")
    ap.add_argument("--out", default=".",
                    help="directory for BENCH_mesh.json")
    args = ap.parse_args()

    print("name,us_per_call,derived")
    report = {"smoke": bool(args.smoke)}
    for section, bench in (("scaling", tick_scaling),
                           ("transfer_audit", transfer_audit)):
        rows, rep = bench(smoke=args.smoke)
        for name, us, derived in rows:
            print(f"{name},{us:.1f},{derived:.6g}", flush=True)
        report[section] = rep
    path = os.path.join(args.out, "BENCH_mesh.json")
    with open(path, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
        f.write("\n")
    shards = report["scaling"]["shards"]
    tops = max(int(s) for s in shards)
    print(f"# wrote {path} (critical-path "
          f"{shards[str(tops)]['critical_path_speedup']:.2f}x at "
          f"{tops} shards on {report['scaling']['cells']} cells; "
          f"largest collective "
          f"{report['transfer_audit']['largest_collective_elements']} "
          f"elements <= stat-row cap "
          f"{report['transfer_audit']['stat_row_cap_elements']})",
          flush=True)


if __name__ == "__main__":
    main()
