"""§Perf Cell C: lower the qwen2.5-32b train_4k multi-pod step with each
telemetry mode and report the collective/flop deltas from the compiled HLO.

This is the paper's contribution measured in its framework context: ISLA's
moment-only state makes the robust (outlier-excluding) statistic O(1) in
communication, while the exact robust competitor (trimmed mean) must gather
and sort the global per-token tensor.

Run (expensive — compiles 4 variants):
  PYTHONPATH=src python -m benchmarks.telemetry_hlo
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import json  # noqa: E402

from repro.launch.dryrun import lower_cell  # noqa: E402
from repro.roofline import analyze_cost, parse_and_cost  # noqa: E402
from repro.configs import SHAPES, get_config  # noqa: E402
from repro.train.train_step import TrainConfig  # noqa: E402


def main():
    cfg = get_config("qwen2.5-32b")
    rows = {}
    for mode in ("off", "isla", "exact", "trimmed_exact"):
        tcfg = TrainConfig(telemetry_mode=mode,
                           isla_telemetry=(mode != "off"))
        lowered, meta, _ = lower_cell("qwen2.5-32b", "train_4k",
                                      multi_pod=True, tcfg=tcfg)
        compiled = lowered.compile()
        cost = parse_and_cost(compiled.as_text())
        r = analyze_cost(cost, cfg, SHAPES["train_4k"], meta["devices"])
        rows[mode] = r
        print(f"{mode:14s} coll_bytes={r['collective_bytes_per_dev']:.4e} "
              f"flops={r['hlo_flops_per_dev']:.4e} "
              f"hbm={r['hlo_bytes_per_dev']:.4e}", flush=True)
    base = rows["off"]
    print("\nname,us_per_call,derived")
    for mode in ("isla", "exact", "trimmed_exact"):
        d_coll = rows[mode]["collective_bytes_per_dev"] \
            - base["collective_bytes_per_dev"]
        d_hbm = rows[mode]["hlo_bytes_per_dev"] - base["hlo_bytes_per_dev"]
        print(f"telemetry_hlo/{mode}_added_coll_bytes,0,{d_coll:.6g}")
        print(f"telemetry_hlo/{mode}_added_hbm_bytes,0,{d_hbm:.6g}")
    with open("dryrun_out/telemetry_modes.json", "w") as f:
        json.dump({m: {k: v for k, v in r.items()
                       if isinstance(v, (int, float, str))}
                   for m, r in rows.items()}, f, indent=1)


if __name__ == "__main__":
    main()
