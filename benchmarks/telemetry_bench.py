"""Framework-level benchmarks: ISLA telemetry vs exact reduction, and the
Pallas Phase-1 kernel (interpret mode on CPU — correctness-grade timing; the
collective-payload numbers are exact and platform-independent).
"""
from __future__ import annotations

import time
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.distributed import exact_mean, isla_mean
from repro.core.types import IslaParams

Row = Tuple[str, float, float]


def _time_jit(fn, *args, iters=20) -> float:
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def telemetry_collective_payload() -> List[Row]:
    """Collective payload of the loss-stats aggregation across a mesh:
    exact mean needs a full-width reduction of B*S values; ISLA psums
    13 floats.  derived = payload ratio (exact / isla)."""
    rows: List[Row] = []
    for (bsz, seq) in [(256, 4096), (32, 32768)]:
        exact_bytes = 4 * 2  # (sum, n) — exact mean after local reduce
        exact_full = bsz * seq * 4  # naive all-gather of per-token losses
        isla_bytes = (3 + 10) * 4
        rows.append((f"telemetry/payload_ratio_gather_B{bsz}xS{seq}",
                     0.0, exact_full / isla_bytes))
        rows.append((f"telemetry/payload_ratio_reduced_B{bsz}xS{seq}",
                     0.0, exact_bytes / isla_bytes))
    return rows


def telemetry_accuracy_speed() -> List[Row]:
    """Wall time + accuracy of isla_mean vs exact_mean on one device."""
    rng = np.random.default_rng(0)
    p = IslaParams(e=0.01)
    x = jnp.asarray(rng.normal(5.5, 1.5, size=(256, 4096)), jnp.float32)
    f_isla = jax.jit(lambda v: isla_mean(v, p, rate=0.02))
    f_exact = jax.jit(exact_mean)
    t_isla = _time_jit(f_isla, x)
    t_exact = _time_jit(f_exact, x)
    err = abs(float(f_isla(x)) - float(f_exact(x)))
    return [
        ("telemetry/isla_mean_us", t_isla, err),
        ("telemetry/exact_mean_us", t_exact, 0.0),
    ]


def kernel_bench() -> List[Row]:
    """isla_moments Pallas kernel (interpret on CPU) vs jnp reference —
    derived = max abs rel error vs oracle."""
    from repro.kernels import ops, ref
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(100, 20, size=(512, 128)), jnp.float32)
    bounds = jnp.asarray([60., 90., 110., 140.], jnp.float32)
    got = ops.isla_moments(x, bounds, tm=64)
    want = ref.isla_moments_ref(x, 60., 90., 110., 140.)
    rel = float(jnp.max(jnp.abs(got - want) / (jnp.abs(want) + 1e-9)))
    t = _time_jit(lambda v: ops.isla_moments(v, bounds, tm=64), x, iters=5)
    return [("kernel/isla_moments_interp_us", t, rel)]
