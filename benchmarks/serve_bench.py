"""Multi-tenant traffic-replay benchmark for the ISLA admission tier.

Replays a skewed mixed-tenant query stream (>= 4:1 queries per StoreKey;
1000 queries/tick at full size) through two `IslaAdmissionLoop`s over
identical warm stores:

 * **admission** — the production pipeline: PlanCache'd steady-state
   planning, exact same-tick dedupe, subsumption serving (a weaker
   ``(e, beta)`` ask on a cached key draws ZERO new samples), and
   priority-ordered admission;
 * **fifo** — the uncached PR-7 baseline (``admission=False`` on a
   ``plan_cache_size=0`` executor): every query plans and composes in
   host Python every tick.

Headlines (recorded in ``BENCH_serve.json``):
 * **throughput** — steady-state queries/sec per route, p50/p99 tick
   latency, and the admission/fifo speedup (must be >= 3x at full size);
 * **plan-cache hit rate** — fraction of steady-phase plans served from
   the PlanCache (must be >= 0.9);
 * **subsumption audit** — every subsumed/deduped answer drew 0 new
   samples and reports a bound no looser than asked;
 * **answer parity** — every ticket's VALUE (and per-group values) is
   bit-identical (host float64) between the two routes on the same RNG
   stream, and the bound-earned flags agree ticket for ticket.

Contract: rows print as ``(name, us_per_call, derived)``; ``--smoke``
shrinks sizes for CI; ``--out DIR`` picks where BENCH_serve.json lands.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import numpy as np

from repro.core.engine import IslaQuery
from repro.core.multiquery import MultiQueryExecutor, table_sampler
from repro.core.types import IslaParams, Predicate
from repro.launch.serve import IslaAdmissionLoop

try:
    from ._timing import time_each
except ImportError:          # script mode: python benchmarks/serve_bench.py
    from _timing import time_each

MU, SIGMA = 100.0, 12.0


def _tenant_tables(n_blocks, rows, seed=0):
    """Relational blocks: measure, binary flag, day-clustered ingest
    column, integer region key — the serve tier's synthetic shape."""
    rng = np.random.default_rng(seed)
    n_days = max(n_blocks // 2, 1)
    tables = []
    for b in range(n_blocks):
        g = rng.integers(0, 4, size=rows)
        tables.append({
            "value": rng.normal(MU + 3.0 * g, SIGMA, rows),
            "region": g.astype(np.float64),
            "flag": rng.integers(0, 2, size=rows).astype(np.float64),
            "day": np.full(rows, float(b % n_days)),
        })
    return tables


def _executor(tables, sizes, plan_cache_size=256):
    return MultiQueryExecutor(
        [table_sampler(t) for t in tables], sizes,
        params=IslaParams(e=0.5), group_domains={"region": 4},
        plan_cache_size=plan_cache_size)


def _templates():
    """The tenant workload's query pool.

    ``warm``: strong demands whose answers EARN their bound and enter the
    subsumption cache — steady-state repeats and weaker variants are
    served with zero new samples.  ``execute``: VAR / grouped-SUM
    demands whose bounds are honest ``None`` (never cacheable) — they
    re-execute every tick, which is exactly the traffic the PlanCache
    amortizes.  Priorities 4..1 pin the executed batch's admission
    order."""
    flag1 = Predicate(column="flag", eq=1.0)
    day0 = Predicate(column="day", eq=0.0)
    warm = [
        IslaQuery(e=0.5, beta=0.95, agg="AVG"),
        IslaQuery(e=0.5, beta=0.95, agg="AVG", where=flag1),
        IslaQuery(e=0.5, beta=0.95, agg="AVG", where=day0),
        IslaQuery(e=0.5, beta=0.95, agg="AVG", group_by="region"),
        IslaQuery(e=0.5, beta=0.95, agg="COUNT"),
        IslaQuery(e=0.5, beta=0.95, agg="COUNT", where=flag1),
        IslaQuery(e=0.5, beta=0.95, agg="SUM"),
    ]
    execute = [
        IslaQuery(e=0.5, beta=0.95, agg="VAR", priority=4.0),
        IslaQuery(e=0.5, beta=0.95, agg="VAR", where=flag1, priority=3.0),
        IslaQuery(e=0.5, beta=0.95, agg="SUM", group_by="region",
                  priority=2.0),
        IslaQuery(e=0.5, beta=0.95, agg="VAR", group_by="region",
                  priority=1.0),
    ]
    # Weaker demands on the warm keys: dominated by the cached answers.
    weak = [dataclasses.replace(q, e=q.e * 2, beta=0.90) for q in warm]
    return warm, execute, weak


def _storekeys(queries):
    return {(q.where, q.group_by, q.mode) for q in queries}


def _tick_traffic(rng, warm, execute, weak, qpt):
    """One tick's submissions: the executed batch first (fixed order),
    then a random mix of warm repeats (subsumed), weak variants
    (subsumed), and exact duplicates of the executed set (deduped)."""
    out = list(execute)
    picks = rng.integers(0, 3, size=max(qpt - len(execute), 0))
    for p in picks:
        pool = (warm, weak, execute)[int(p)]
        out.append(pool[int(rng.integers(0, len(pool)))])
    return out


def _drive(loop, traffic_per_tick):
    """Submit + tick each steady round; returns per-tick seconds
    (submission and the drain/assert run untimed around each tick)."""
    def _submit(batch):
        for q in batch:
            loop.submit(q)

    def _check(batch, done):
        while loop.pending:  # FIFO overflow safety; no-op normally
            done += loop.tick()
        if len(done) != len(batch):
            raise AssertionError(
                f"tick answered {len(done)} of {len(batch)} queries")

    return time_each(lambda _batch: loop.tick(), traffic_per_tick,
                     setup=_submit, after=_check)


def traffic_replay(smoke=False):
    """Admission vs uncached-FIFO on identical skewed tenant traffic."""
    n_blocks, rows, qpt, steady = ((12, 1200, 128, 6) if smoke
                                   else (48, 3000, 1000, 12))
    tables = _tenant_tables(n_blocks, rows)
    sizes = [10 ** 6] * n_blocks
    warm, execute, weak = _templates()
    n_keys = len(_storekeys(warm + execute + weak))
    skew = qpt / n_keys
    if skew < 4.0:
        raise AssertionError(f"traffic skew {skew:.1f}:1 below the 4:1 "
                             "queries-per-StoreKey floor")

    # Pre-generate identical steady traffic for both routes.
    trng = np.random.default_rng(11)
    traffic = [_tick_traffic(trng, warm, execute, weak, qpt)
               for _ in range(steady)]

    loops = {}
    for name in ("admission", "fifo"):
        ex = _executor(tables, sizes,
                       plan_cache_size=0 if name == "fifo" else 256)
        loop = IslaAdmissionLoop(ex, np.random.default_rng(3),
                                 max_batch=max(qpt, 1024),
                                 incremental=True,
                                 admission=(name == "admission"))
        # Warm-up: every template once (identical RNG draws per route),
        # then one steady-shaped tick so the steady plan is cached.
        for q in warm + execute + weak:
            loop.submit(q)
        loop.run_until_drained()
        wrng = np.random.default_rng(11)
        _drive(loop, [_tick_traffic(wrng, warm, execute, weak, qpt)])
        loops[name] = loop

    results, answers = {}, {}
    for name, loop in loops.items():
        before = loop.stats
        n0 = len(loop.answered)
        t0 = time.perf_counter()
        times = _drive(loop, traffic)
        wall = time.perf_counter() - t0
        s = loop.stats
        steady_tickets = loop.answered[n0:]
        earned = [t for t in steady_tickets
                  if t.answer.error_bound is not None]
        hits = s["plan_cache_hits"] - before["plan_cache_hits"]
        misses = s["plan_cache_misses"] - before["plan_cache_misses"]
        results[name] = {
            "queries": len(steady_tickets),
            "qps": len(steady_tickets) / max(wall, 1e-9),
            "p50_ms": float(np.percentile(times, 50) * 1e3),
            "p99_ms": float(np.percentile(times, 99) * 1e3),
            "bound_earned_fraction": len(earned) / len(steady_tickets),
            "steady_new_samples":
                s["samples_drawn"] - before["samples_drawn"],
            "plan_cache_hit_rate":
                hits / max(hits + misses, 1) if name == "admission"
                else None,
            "subsumed": s["subsumed"] - before["subsumed"],
            "deduped": s["deduped"] - before["deduped"],
        }
        answers[name] = {t.tid: t.answer for t in loop.answered}

    adm, fifo = results["admission"], results["fifo"]
    # Steady state must be draw-free on BOTH routes (the bit-parity
    # precondition: zero draws -> zero RNG consumed -> same stores).
    for name, r in results.items():
        if r["steady_new_samples"] != 0:
            raise AssertionError(f"{name} route drew "
                                 f"{r['steady_new_samples']} steady "
                                 "samples; warm-up did not converge")
    # Every subsumed/deduped answer drew zero new samples, with a bound
    # no looser than asked.
    zero_checked = 0
    for t in loops["admission"].answered:
        a = t.answer
        if a.served in ("subsumed", "dedupe"):
            if a.new_samples != 0:
                raise AssertionError(f"{a.served} answer drew "
                                     f"{a.new_samples} samples")
            if a.error_bound is not None and a.query.agg == "AVG" \
                    and a.error_bound > t.query.e + 1e-12:
                raise AssertionError("served bound looser than asked")
            zero_checked += 1
    if adm["subsumed"] == 0 or adm["deduped"] == 0:
        raise AssertionError("traffic exercised no subsumption/dedupe")
    hit_rate = adm["plan_cache_hit_rate"]
    if hit_rate < 0.9:
        raise AssertionError(f"steady plan-cache hit rate {hit_rate:.2f} "
                             "below 0.9")
    # Bit parity (host float64): identical values, group rows, and
    # bound-earned flags per ticket across both routes.
    if set(answers["admission"]) != set(answers["fifo"]):
        raise AssertionError("routes answered different ticket sets")
    for tid, a in answers["admission"].items():
        f = answers["fifo"][tid]
        if not _bit_identical(a, f):
            raise AssertionError(f"ticket {tid} diverged: "
                                 f"{a.value!r} vs {f.value!r}")
    speedup = adm["qps"] / max(fifo["qps"], 1e-9)
    if not smoke and speedup < 3.0:
        raise AssertionError(f"admission speedup {speedup:.2f}x below the "
                             "3x floor vs the FIFO loop")
    rows = [
        (f"fifo_tick/q{qpt}", fifo["p50_ms"] * 1e3, fifo["qps"]),
        (f"admission_tick/q{qpt}", adm["p50_ms"] * 1e3, adm["qps"]),
        ("admission_speedup_x", 0.0, speedup),
        ("plan_cache_hit_rate", 0.0, hit_rate),
        ("answer_parity_ok", 0.0, 1.0),
    ]
    return rows, {
        "queries_per_tick": qpt, "steady_ticks": steady,
        "distinct_storekeys": n_keys, "skew_queries_per_storekey": skew,
        "admission": adm, "fifo": fifo, "speedup_x": speedup,
        "plan_cache_hit_rate": hit_rate,
        "subsumed_zero_new_samples_checked": zero_checked,
        "parity": {"dtype": "float64 (host route)",
                   "bit_identical": True,
                   "tickets_compared": len(answers["admission"])},
    }


def _bit_identical(a, f) -> bool:
    """Same value bits, same group rows, same bound-earned flag.  The
    BOUND itself may legitimately differ on a served answer: a subsumed
    ask inherits its dominator's bound, which holds at the dominator's
    HIGHER confidence and so can be numerically wider than a fresh
    compose at the weaker asked beta.  Computed answers must match the
    FIFO bound exactly."""
    va, vf = float(a.value), float(f.value)
    if not (va == vf or (np.isnan(va) and np.isnan(vf))):
        return False
    if (a.error_bound is None) != (f.error_bound is None):
        return False
    if a.served is None and a.error_bound is not None \
            and a.error_bound != f.error_bound:
        return False
    ga = a.groups or []
    gf = f.groups or []
    if len(ga) != len(gf):
        return False
    for x, y in zip(ga, gf):
        vx, vy = float(x.value), float(y.value)
        if not (vx == vy or (np.isnan(vx) and np.isnan(vy))):
            return False
    return True


def progressive_stream(smoke=False):
    """OLA streaming under a tight tick budget: the in-flight ticket's
    half-width snapshots shrink monotonically-ish until the bound is
    earned, then the ticket completes."""
    n_blocks, rows = (8, 1200) if smoke else (24, 2500)
    tables = _tenant_tables(n_blocks, rows, seed=5)
    ex = _executor(tables, [10 ** 6] * n_blocks)
    loop = IslaAdmissionLoop(ex, np.random.default_rng(9),
                             incremental=True, deadline_samples=400,
                             progressive=True)
    loop.submit(IslaQuery(e=0.35, beta=0.95, agg="AVG",
                          where=Predicate(column="flag", eq=1.0)))
    t0 = time.perf_counter()
    done = loop.run_until_drained(max_ticks=400)
    us = (time.perf_counter() - t0) * 1e6
    if len(done) != 1:
        raise AssertionError("progressive ticket never earned its bound")
    t = done[0]
    widths = [hw for (_, _, hw, _) in t.progress if hw is not None]
    if len(widths) < 2 or not widths[-1] < widths[0]:
        raise AssertionError(f"half-width stream did not shrink: {widths}")
    if t.answer.error_bound is None:
        raise AssertionError("completed ticket carries no earned bound")
    rows_out = [("progressive_ticks_to_bound", us,
                 float(len(t.progress)))]
    return rows_out, {
        "ticks_to_bound": len(t.progress),
        "first_half_width": widths[0], "final_half_width": widths[-1],
        "budget_per_tick": 400,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes so CI can keep the entrypoints alive")
    ap.add_argument("--out", default=".",
                    help="directory for BENCH_serve.json")
    args = ap.parse_args()

    print("name,us_per_call,derived")
    report = {"smoke": bool(args.smoke)}
    for section, bench in (("traffic", traffic_replay),
                           ("progressive", progressive_stream)):
        rows, rep = bench(smoke=args.smoke)
        for name, us, derived in rows:
            print(f"{name},{us:.1f},{derived:.6g}", flush=True)
        report[section] = rep
    os.makedirs(args.out, exist_ok=True)
    path = os.path.join(args.out, "BENCH_serve.json")
    with open(path, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
        f.write("\n")
    tr = report["traffic"]
    print(f"# wrote {path} ({tr['speedup_x']:.1f}x queries/sec vs FIFO at "
          f"{tr['queries_per_tick']} q/tick, "
          f"{tr['skew_queries_per_storekey']:.0f}:1 skew, plan-cache hit "
          f"rate {tr['plan_cache_hit_rate']:.2f}, answers bit-identical)",
          flush=True)


if __name__ == "__main__":
    main()
