"""Render the §Roofline markdown table from a dry-run output directory.

  PYTHONPATH=src python -m benchmarks.roofline_report [dir] [--mesh single]
"""
from __future__ import annotations

import glob
import json
import os
import sys

from repro.roofline.analysis import suggest


def rows_from(dir_: str, mesh: str = "single"):
    out = []
    for path in sorted(glob.glob(os.path.join(dir_, f"*__{mesh}.json"))):
        r = json.load(open(path))
        arch, shape, _ = os.path.basename(path)[:-5].split("__")
        if r.get("status") == "skip":
            out.append({"arch": arch, "shape": shape, "skip": True,
                        "reason": r.get("reason", "")})
            continue
        if r.get("status") != "ok" or "roofline" not in r:
            out.append({"arch": arch, "shape": shape, "fail": True})
            continue
        rf = r["roofline"]
        out.append({
            "arch": arch, "shape": shape,
            "compute_s": rf["compute_s"], "memory_s": rf["memory_s"],
            "collective_s": rf["collective_s"], "dominant": rf["dominant"],
            "frac": rf["roofline_fraction"],
            "mh": rf["model_to_hlo_flops"],
            "note": suggest(rf),
        })
    return out


def markdown(dir_: str, mesh: str = "single") -> str:
    lines = [
        f"| arch | shape | compute s | memory s | collective s | dominant "
        f"| MODEL/HLO | roofline frac |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in rows_from(dir_, mesh):
        if r.get("skip"):
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"{r['reason'][:40]} | — | — |")
        elif r.get("fail"):
            lines.append(f"| {r['arch']} | {r['shape']} | FAIL | | | | | |")
        else:
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3f} | "
                f"{r['memory_s']:.3f} | {r['collective_s']:.3f} | "
                f"{r['dominant']} | {r['mh']:.2f} | {r['frac']:.4f} |")
    return "\n".join(lines)


if __name__ == "__main__":
    d = sys.argv[1] if len(sys.argv) > 1 else "dryrun_out_final"
    mesh = sys.argv[2] if len(sys.argv) > 2 else "single"
    print(markdown(d, mesh))
