"""Device-resident tick benchmark: host-merge vs fused device continuation.

Headlines (recorded in ``BENCH_device.json``):
 * **tick speedup** — the steady-state serving tick (merge a fresh pass
   + Phase 2 + group stats for FOUR warm (where, group_by) keys at
   16 groups x 1000 blocks) as ONE fused stacked launch
   (``DeviceStack.tick`` -> ``distributed.fused_tick_dense``) vs the
   PR-3 path that host-merges each key's store in float64 numpy and
   ships its moments to the device every tick, answers cross-checked;
 * **transfer counts** — a steady-state tick performs ZERO host<->device
   moment transfers: the whole tick runs under
   ``jax.transfer_guard("disallow")`` with only the sanctioned sample
   uploads (``distributed.h2d``: quotas, value pane, pad mask, GROUP BY
   pane — 4 sample-sized crossings) allowed, asserted by counting
   ``h2d`` calls;
 * **dense fused launch** — ``kernels.isla_fused_pallas`` chains the
   Pallas Phase 1 accumulator (prior operand) into the branchless
   Phase 2 in one jit (latency probe; interpret-mode on CPU, the
   compiled win is TPU-side).

Contract: rows print as ``(name, us_per_call, derived)``; ``--smoke``
shrinks sizes for CI; ``--out DIR`` picks where BENCH_device.json lands.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from repro.core.boundaries import make_boundaries
from repro.core.moment_store import DeviceMomentStore, MomentStore
from repro.core.types import IslaParams

try:
    from ._timing import time_best
except ImportError:          # script mode: python benchmarks/device_bench.py
    from _timing import time_best

MU, SIGMA = 100.0, 20.0


def _host_group_stats(store: MomentStore, partials: np.ndarray,
                      block_sizes: np.ndarray) -> np.ndarray:
    """The host tick's group-stat reduction — the same nine columns
    ``multiquery._keyed_stats`` derives per tick (and the fused device
    launch folds into its single call): per-group n, est. population,
    leverage mean, E[x^2], plain sample sums, fallback degradation, and
    the catalog-weighted second moment."""
    g, b = store.n_groups, store.n_blocks
    cnt = store.totals[:, 0].reshape(g, b)
    s1 = store.totals[:, 1].reshape(g, b)
    s2 = store.totals[:, 2].reshape(g, b)
    weights = (block_sizes[None, :] * cnt
               / np.maximum(store.n_sampled, 1.0)[None, :])
    w_g = weights.sum(axis=1)
    mean_g = (partials.reshape(g, b) * weights).sum(axis=1) \
        / np.maximum(w_g, 1.0)
    per_ex2 = s2 / np.maximum(cnt, 1.0)
    visited = (cnt > 0).astype(np.float64)
    ex2_g = (per_ex2 * weights).sum(axis=1)
    fallback = ((store.mom_s[:, 0] < 1.0)
                | (store.mom_l[:, 0] < 1.0)).reshape(g, b)
    degraded = (fallback & (cnt > 0)).any(axis=1).astype(np.float64)
    cat_num = (per_ex2 * block_sizes[None, :] * visited).sum(axis=1)
    cat_den = (block_sizes[None, :] * visited).sum(axis=1)
    return np.stack([cnt.sum(axis=1), w_g, mean_g, ex2_g,
                     s1.sum(axis=1), s2.sum(axis=1), degraded,
                     cat_num, cat_den], axis=1)


def _make_pass(rng, n_blocks, n_groups, quota):
    vals = rng.normal(MU, SIGMA, n_blocks * quota)
    bids = np.repeat(np.arange(n_blocks), quota)
    gids = rng.integers(0, n_groups, vals.size)
    quotas = np.full(n_blocks, quota, dtype=np.int64)
    return vals, bids, gids, quotas


def _pr3_device_tick(store, vals, bids, gids, mask, quotas, params, sizes):
    """The PR-3 ``route="device"`` incremental tick this PR replaces:
    host-merge the pass in float64 numpy, ship the merged moment rows
    host->device, run the branchless Phase 2 as its own launch, fetch
    the partials back, and reduce group stats on the host."""
    import jax.numpy as jnp

    from repro.core.distributed import phase2

    store.ingest(vals, bids, quotas, group_ids=gids, mask=mask)
    scale = max(abs(store.sketch0), SIGMA, 1e-12)
    pows = np.array([1.0, scale, scale * scale, scale ** 3])
    mom_s = jnp.asarray(store.mom_s / pows, jnp.float32)   # moments h2d
    mom_l = jnp.asarray(store.mom_l / pows, jnp.float32)   # every tick
    avg = phase2(mom_s, mom_l, jnp.float32(store.sketch0 / scale), params,
                 mode="calibrated")
    partials = np.asarray(avg, dtype=np.float64) * scale   # d2h
    return _host_group_stats(store, partials, sizes), partials


def tick_speed(smoke=False):
    """Steady-state serving tick at 16 groups x 1000 blocks: one
    mode-group with four warm (where, group_by) keys — the multi-store
    workload ``IslaAdmissionLoop`` batches — as ONE fused stacked launch
    (``DeviceStack.tick``) vs the PR-3 path that host-merges each key's
    store and ships its moments to the device every tick.

    Per-tick times aggregate by MIN over rounds (the usual
    noisy-shared-host estimator of achievable latency)."""
    from repro.core.moment_store import DeviceStack

    params = IslaParams()
    b = make_boundaries(MU, SIGMA, params)
    n_groups, n_blocks, quota, rounds = ((3, 16, 40, 3) if smoke
                                         else (16, 1000, 64, 10))
    sizes = np.full(n_blocks, 10.0 ** 7)
    rng = np.random.default_rng(0)
    # Four warm keys: plain, WHERE, GROUP BY, WHERE + GROUP BY.
    key_specs = [(False, 1), (True, 1), (False, n_groups),
                 (True, n_groups)]

    def make_pass():
        vals, bids, gids, quotas = _make_pass(rng, n_blocks, n_groups,
                                              quota)
        mask = rng.random(vals.size) < 0.5
        return vals, bids, gids, mask, quotas

    passes = [make_pass() for _ in range(rounds + 1)]

    pr3 = [MomentStore.fresh(n_blocks, b, MU, n_groups=g)
           for _, g in key_specs]
    dstores = [DeviceMomentStore.fresh_device(n_blocks, b, MU, sizes,
                                              n_groups=g)
               for _, g in key_specs]
    stack = DeviceStack(dstores)

    def pr3_tick(p):
        vals, bids, gids, mask, quotas = p
        out = []
        for (pred, g), st in zip(key_specs, pr3):
            out.append(_pr3_device_tick(
                st, vals, bids, gids if g > 1 else None,
                mask if pred else None, quotas, params, sizes))
        return out

    def device_tick(p):
        vals, bids, gids, mask, quotas = p
        key_gids = [gids if g > 1 else None for _, g in key_specs]
        key_valids = [mask if pred else None for pred, _ in key_specs]
        return stack.tick(params, mode="calibrated", values=vals,
                          quotas=quotas, dense=(key_gids, key_valids))

    # Both systems replay the SAME pre-generated passes; the warm-up
    # pass seeds the stores / compiles the fused launch.
    pr3_best, pr3_out = time_best(pr3_tick, passes)
    dev_best, dev_out = time_best(device_tick, passes)

    # Cross-check: every key's group means within fp32 tolerance.
    rel = 0.0
    for (host_rows, _), (_, dev_rows), dst in zip(pr3_out, dev_out,
                                                  dstores):
        dev_mean = (dev_rows[:, 2] * dst.scale
                    / np.maximum(dev_rows[:, 1], 1e-9))
        rel = max(rel, float(np.max(
            np.abs(dev_mean - host_rows[:, 2])
            / np.maximum(np.abs(host_rows[:, 2]), 1e-9))))
    if rel > 1e-3:
        raise AssertionError(f"device tick diverged from host: rel={rel}")
    speedup = pr3_best / max(dev_best, 1e-9)
    cells = stack.n_cells
    rows_out = [
        (f"pr3_hostmerge_ship_tick/c{cells}", pr3_best, 1.0),
        (f"device_resident_tick/c{cells}", dev_best, speedup),
    ]
    return rows_out, {
        "n_groups": n_groups, "n_blocks": n_blocks,
        "keys": len(key_specs), "cells": cells,
        "samples_per_tick": int(n_blocks * quota), "rounds": rounds,
        "pr3_device_route_us_per_tick": pr3_best,
        "device_us_per_tick": dev_best,
        "speedup_vs_pr3_device_route": speedup,
        "group_mean_max_rel_diff": rel,
        "aggregation": "min over rounds",
    }


def transfer_counts(smoke=False):
    """Steady tick under transfer-guard: only the sanctioned sample
    uploads cross host->device — 4 for the dense grouped layout run here
    (quotas, value pane, pad mask, GROUP BY pane), all sample-sized."""
    import jax

    from repro.core import distributed as D

    params = IslaParams()
    b = make_boundaries(MU, SIGMA, params)
    n_groups, n_blocks, quota = (3, 16, 40) if smoke else (16, 200, 64)
    sizes = np.full(n_blocks, 10.0 ** 7)
    rng = np.random.default_rng(1)
    dev = DeviceMomentStore.fresh_device(n_blocks, b, MU, sizes,
                                         n_groups=n_groups)
    v, bi, gi, q = _make_pass(rng, n_blocks, n_groups, quota)
    dev.ingest_tick(v, bi, q, params, group_ids=gi)  # warm / compile

    calls = []
    real_h2d = D.h2d

    def counting_h2d(x, dtype=None):
        calls.append(np.asarray(x).nbytes)
        return real_h2d(x, dtype)

    D.h2d = counting_h2d
    try:
        v, bi, gi, q = _make_pass(rng, n_blocks, n_groups, quota)
        with jax.transfer_guard("disallow"):
            dev.ingest_tick(v, bi, q, params, group_ids=gi)
    finally:
        D.h2d = real_h2d
    # Dense grouped tick ships: quotas, value pane, pad mask, GROUP BY
    # pane — all sample-sized metadata, never moments.
    if len(calls) != 4:
        raise AssertionError(
            f"steady tick made {len(calls)} h2d crossings, expected 4 "
            "(quotas, values, pad mask, group codes)")
    moment_bytes = int(np.asarray(dev.mom_s).nbytes
                       + np.asarray(dev.mom_l).nbytes)
    rows = [("steady_tick_h2d_crossings", 0.0, float(len(calls)))]
    return rows, {
        "sanctioned_h2d_per_tick": len(calls),
        "sanctioned_h2d_bytes": int(sum(calls)),
        "moment_h2d_transfers": 0,
        "resident_moment_bytes_never_shipped": moment_bytes,
        "transfer_guard": "disallow (sanctioned uploads via h2d only)",
    }


def dense_fused(smoke=False):
    """One-launch dense path: Pallas Phase 1 (prior-seeded) + Phase 2
    fused, vs the two-step moments -> host-solve route."""
    import jax.numpy as jnp

    from repro.core.engine import phase2_iteration_batch
    from repro.kernels.isla_moments import (isla_fused_pallas,
                                            isla_moments_batched_pallas)

    params = IslaParams()
    cells, tiles, tm = (4, 1, 64) if smoke else (32, 2, 64)
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(MU, SIGMA, size=(cells, tm * tiles, 128)),
                    jnp.float32)
    bounds = jnp.asarray(make_boundaries(MU, SIGMA, params).as_tuple(),
                         jnp.float32)
    prior = jnp.zeros((cells, 2, 4), jnp.float32)

    t0 = time.perf_counter()
    mom = isla_moments_batched_pallas(x, bounds, tm=tm, interpret=True)
    split_res = phase2_iteration_batch(
        np.asarray(mom[:, 0], np.float64), np.asarray(mom[:, 1], np.float64),
        MU, params, mode="calibrated")
    split_us = (time.perf_counter() - t0) * 1e6
    t0 = time.perf_counter()
    _, partials = isla_fused_pallas(x, bounds, prior, jnp.float32(MU),
                                    params, tm=tm, interpret=True)
    fused_us = (time.perf_counter() - t0) * 1e6
    rel = float(np.max(np.abs(np.asarray(partials, np.float64)
                              - split_res.avg)
                       / np.maximum(np.abs(split_res.avg), 1e-9)))
    if rel > 1e-3:
        raise AssertionError(f"fused dense launch diverged: rel={rel}")
    rows = [
        (f"dense_split_launches/c{cells}", split_us, 1.0),
        (f"dense_fused_launch/c{cells}", fused_us, rel),
    ]
    return rows, {"cells": cells, "interpret": True,
                  "partials_max_rel_diff": rel,
                  "note": "interpret-mode latency probe on CPU; the "
                          "compiled single-launch win is TPU-side"}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes so CI can keep the entrypoints alive")
    ap.add_argument("--out", default=".",
                    help="directory for BENCH_device.json")
    args = ap.parse_args()

    print("name,us_per_call,derived")
    report = {"smoke": bool(args.smoke)}
    for section, bench in (("tick", tick_speed),
                           ("transfers", transfer_counts),
                           ("dense", dense_fused)):
        rows, rep = bench(smoke=args.smoke)
        for name, us, derived in rows:
            print(f"{name},{us:.1f},{derived:.6g}", flush=True)
        report[section] = rep
    path = os.path.join(args.out, "BENCH_device.json")
    with open(path, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
        f.write("\n")
    speedup = report["tick"]["speedup_vs_pr3_device_route"]
    print(f"# wrote {path} (device tick {speedup:.2f}x "
          f"vs host merge at {report['tick']['cells']} cells; "
          f"{report['transfers']['sanctioned_h2d_per_tick']} sanctioned "
          f"h2d crossings, 0 moment transfers)", flush=True)


if __name__ == "__main__":
    main()
