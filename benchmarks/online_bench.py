"""Online-refinement benchmark: continuation rounds vs one-shot resampling,
warm-store reuse in the incremental executor, and per-key anchor
refinement under a measure-correlated predicate.

Headlines (recorded in ``BENCH_online.json``):
 * **merge parity** — k continuation rounds through ``MomentStore`` are
   bit-identical per (group, block) cell to a single pass over the
   concatenated stream (asserted; the benchmark is invalid otherwise);
 * **rounds-to-target-error** — refining one persistent store round after
   round reaches the target error with k-times fewer samples than re-
   sampling from scratch each time a tighter answer is demanded (the
   §VII-A online claim, quantified);
 * **warm-store reuse** — a repeated predicate through
   ``run(incremental=True)`` draws STRICTLY fewer new samples than a cold
   ``execute()`` of the same query (zero when the deficit is <= 0) — the
   acceptance criterion of the incremental serving path;
 * **refined anchors** — under a measure-correlated WHERE (the predicate
   selects the measure's own upper tail) the per-key refined anchor
   earns the (e, beta) bound with FEWER samples than the global anchor
   at (much better) accuracy: the global boundaries leave the matching
   sub-population's S region empty, so the global path degrades to the
   relaxed sketch while still paying the pooled-sigma sample bill.

Contract: rows print as ``(name, us_per_call, derived)`` like the other
benches; ``--smoke`` shrinks sizes so CI keeps the entrypoint alive;
``--out DIR`` picks where BENCH_online.json lands.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from repro.core.boundaries import make_boundaries
from repro.core.engine import IslaQuery, phase1_sampling_batch
from repro.core.moment_store import MomentStore
from repro.core.multiquery import MultiQueryExecutor, table_sampler
from repro.core.types import IslaParams, Predicate

MU, SIGMA = 100.0, 20.0


def _samplers(b):
    return [(lambda n, rng, m=MU, s=SIGMA: rng.normal(m, s, size=n))
            for _ in range(b)]


def merge_parity(smoke=False):
    """k ingest rounds == one concatenated stream, bit-for-bit per cell."""
    params = IslaParams()
    b = make_boundaries(MU, SIGMA, params)
    n_blocks, n_groups, m = (4, 2, 400) if smoke else (32, 4, 4000)
    rng = np.random.default_rng(0)
    vals = rng.normal(MU, SIGMA, size=n_blocks * m)
    block_ids = np.repeat(np.arange(n_blocks), m)
    group_ids = rng.integers(0, n_groups, size=vals.size)
    mask = rng.random(vals.size) < 0.8

    whole_s, whole_l = phase1_sampling_batch(
        vals, block_ids, n_blocks, b, group_ids=group_ids,
        n_groups=n_groups, mask=mask)
    k = 5
    t0 = time.perf_counter()
    store = MomentStore.fresh(n_blocks, b, MU, n_groups=n_groups)
    cuts = np.linspace(0, vals.size, k + 1).astype(int)
    for lo, hi in zip(cuts[:-1], cuts[1:]):
        sl = slice(lo, hi)
        store.ingest(vals[sl], block_ids[sl],
                     np.bincount(block_ids[sl], minlength=n_blocks),
                     group_ids=group_ids[sl], mask=mask[sl])
    us = (time.perf_counter() - t0) * 1e6
    if not (np.array_equal(store.mom_s, whole_s)
            and np.array_equal(store.mom_l, whole_l)):
        raise AssertionError("k rounds != one stream — benchmark invalid")
    return [(f"store_merge_{k}rounds/b{n_blocks}g{n_groups}", us, 1.0)], {
        "rounds": k, "n_blocks": n_blocks, "n_groups": n_groups,
        "bit_identical": True}


def rounds_to_target(smoke=False):
    """Progressive refinement on a fixed demand schedule: round r demands
    the precision of r * per_round samples per block.  Both paths serve
    identical demands with identical per-round statistical power; the
    online store merges each round's draw (top-up = per_round), while the
    one-shot baseline re-samples its whole stream from scratch every round
    — a sum-of-rounds vs last-round sample bill ((R+1)/2 at R rounds)."""
    params = IslaParams(e=0.1)
    n_blocks = 8 if smoke else 50
    per_round = 200 if smoke else 1000
    rounds = 4 if smoke else 8
    sizes = [10 ** 7] * n_blocks
    seeds = range(3 if smoke else 8)

    online_samples, oneshot_samples = [], []
    online_err, oneshot_err = [], []
    online_us = oneshot_us = 0.0
    for seed in seeds:
        b = make_boundaries(MU + 0.3, SIGMA, params)
        # Online: ONE store, merged round after round.
        store = MomentStore.fresh(n_blocks, b, MU + 0.3)
        rng = np.random.default_rng(seed)
        samplers = _samplers(n_blocks)
        t0 = time.perf_counter()
        for _ in range(rounds):
            res = store.continue_rounds(
                samplers, sizes, per_round / 10 ** 7, params, rng,
                mode="calibrated", reanchor=True)
        online_us += (time.perf_counter() - t0) * 1e6
        online_samples.append(store.total_sampled)
        online_err.append(abs(store.answer(res.avg, sizes) - MU))

        # One-shot resampling: every demand draws its stream from scratch.
        rng = np.random.default_rng(seed)
        spent = 0
        t0 = time.perf_counter()
        for round_ in range(1, rounds + 1):
            fresh = MomentStore.fresh(n_blocks, b, MU + 0.3)
            res = fresh.continue_rounds(
                samplers, sizes, round_ * per_round / 10 ** 7, params, rng,
                mode="calibrated")
            spent += fresh.total_sampled
        oneshot_us += (time.perf_counter() - t0) * 1e6
        oneshot_samples.append(spent)
        oneshot_err.append(abs(fresh.answer(res.avg, sizes) - MU))

    n = len(online_samples)
    mean_online = float(np.mean(online_samples))
    mean_oneshot = float(np.mean(oneshot_samples))
    ratio = mean_oneshot / mean_online
    rows = [
        (f"online_refine/b{n_blocks}r{rounds}", online_us / n, mean_online),
        (f"oneshot_resample/b{n_blocks}r{rounds}", oneshot_us / n,
         mean_oneshot),
        ("online_sample_ratio", online_us / n, ratio),
    ]
    report = {
        "n_blocks": n_blocks, "per_round": per_round, "rounds": rounds,
        "online_mean_samples": mean_online,
        "oneshot_mean_samples": mean_oneshot,
        "oneshot_over_online": ratio,
        "online_mean_final_abs_err": float(np.mean(online_err)),
        "oneshot_mean_final_abs_err": float(np.mean(oneshot_err)),
    }
    return rows, report


def warm_store_reuse(smoke=False):
    """The acceptance run: cold execute vs warm repeat of one predicate."""
    n_blocks, n_groups, rows_per = (6, 3, 2000) if smoke else (100, 8, 8192)
    sizes = [10 ** 7] * n_blocks
    rng = np.random.default_rng(2)
    tables = []
    for _ in range(n_blocks):
        g = rng.integers(0, n_groups, size=rows_per)
        tables.append({
            "value": rng.normal(MU - 8.0 + 2.0 * g, SIGMA),
            "region": g.astype(np.float64),
            "flag": rng.integers(0, 2, size=rows_per).astype(np.float64),
        })
    e = 1.0 if smoke else 0.5
    query = IslaQuery(e=e, agg="AVG", group_by="region",
                      where=Predicate(column="flag", eq=1.0))

    def mk():
        return MultiQueryExecutor(
            [table_sampler(t) for t in tables], sizes,
            params=IslaParams(e=e), group_domains={"region": n_groups})

    cold_ex = mk()
    t0 = time.perf_counter()
    (cold,) = cold_ex.run([query], np.random.default_rng(3))
    cold_us = (time.perf_counter() - t0) * 1e6

    warm_ex = mk()
    (first,) = warm_ex.run([query], np.random.default_rng(3),
                           incremental=True)
    t0 = time.perf_counter()
    (warm,) = warm_ex.run([query], np.random.default_rng(4),
                          incremental=True)
    warm_us = (time.perf_counter() - t0) * 1e6

    if not warm.new_samples < cold.sample_size:
        raise AssertionError(
            f"warm repeat drew {warm.new_samples} >= cold "
            f"{cold.sample_size} — the warm store is not reusing work")
    rows = [
        (f"cold_execute/b{n_blocks}g{n_groups}", cold_us,
         float(cold.sample_size)),
        (f"warm_repeat/b{n_blocks}g{n_groups}", warm_us,
         float(warm.new_samples)),
        ("warm_speedup", warm_us, cold_us / max(warm_us, 1e-9)),
    ]
    report = {
        "n_blocks": n_blocks, "n_groups": n_groups, "e": e,
        "cold_samples": int(cold.sample_size),
        "first_incremental_new_samples": int(first.new_samples),
        "warm_repeat_new_samples": int(warm.new_samples),
        "warm_strictly_fewer_than_cold": bool(
            warm.new_samples < cold.sample_size),
        "cold_us": cold_us, "warm_us": warm_us,
        "warm_speedup": cold_us / max(warm_us, 1e-9),
    }
    return rows, report


def refined_anchor_predicate(smoke=False):
    """The acceptance experiment for per-key leverage anchors: AVG over a
    measure-correlated WHERE (value >= mu + 1.5 sigma), refined vs global
    anchor, multi-seed.  Records samples drawn, whether the (e, beta)
    bound was earned, and the absolute error against the population
    truth of the with-replacement sampling model."""
    n_blocks, rows_per = (4, 4000) if smoke else (8, 40000)
    e = 1.0 if smoke else 0.5
    seeds = range(2 if smoke else 8)
    cut = MU + 1.5 * SIGMA
    where = Predicate(column="value", lo=cut)
    sizes = [10 ** 7] * n_blocks

    stats = {True: {"samples": [], "err": [], "earned": [], "us": 0.0},
             False: {"samples": [], "err": [], "earned": [], "us": 0.0}}
    for seed in seeds:
        rng = np.random.default_rng(100 + seed)
        tables = [{"value": rng.normal(MU, SIGMA, size=rows_per)}
                  for _ in range(n_blocks)]
        match = np.concatenate([t["value"][t["value"] >= cut]
                                for t in tables])
        truth = float(np.mean(match))
        for refine in (True, False):
            ex = MultiQueryExecutor(
                [table_sampler(t) for t in tables], sizes,
                params=IslaParams(e=e), refine_anchors=refine,
                anchor_min_support=24)
            t0 = time.perf_counter()
            (ans,) = ex.run([IslaQuery(e=e, agg="AVG", where=where)],
                            np.random.default_rng(200 + seed))
            stats[refine]["us"] += (time.perf_counter() - t0) * 1e6
            stats[refine]["samples"].append(int(ans.sample_size))
            stats[refine]["err"].append(abs(float(ans.value) - truth))
            stats[refine]["earned"].append(ans.error_bound is not None)

    n = len(stats[True]["samples"])
    ref_s = float(np.mean(stats[True]["samples"]))
    glo_s = float(np.mean(stats[False]["samples"]))
    ref_err = float(np.mean(stats[True]["err"]))
    glo_err = float(np.mean(stats[False]["err"]))
    if not ref_s < glo_s:
        raise AssertionError(
            f"refined anchors drew {ref_s} samples >= global {glo_s} — "
            "the matching-rows sigma is not steering the rate")
    if not ref_err < glo_err:
        raise AssertionError(
            f"refined anchors erred {ref_err} >= global {glo_err} at "
            "fewer samples — refinement is not helping accuracy")
    earned_ref = float(np.mean(stats[True]["earned"]))
    earned_glo = float(np.mean(stats[False]["earned"]))
    if n >= 4 and not earned_ref > earned_glo:
        raise AssertionError(
            f"refined anchors earned the bound on {earned_ref:.0%} of "
            f"seeds vs global {earned_glo:.0%} — the S/L regions are "
            "not being repopulated")
    rows = [
        (f"refined_anchor/b{n_blocks}", stats[True]["us"] / n, ref_s),
        (f"global_anchor/b{n_blocks}", stats[False]["us"] / n, glo_s),
        ("refined_sample_ratio", stats[True]["us"] / n, glo_s / ref_s),
    ]
    report = {
        "n_blocks": n_blocks, "e": e, "seeds": n,
        "predicate": where.describe(),
        "refined_mean_samples": ref_s,
        "global_mean_samples": glo_s,
        "global_over_refined_samples": glo_s / ref_s,
        "refined_mean_abs_err": ref_err,
        "global_mean_abs_err": glo_err,
        "refined_bound_earned_frac": float(
            np.mean(stats[True]["earned"])),
        "global_bound_earned_frac": float(
            np.mean(stats[False]["earned"])),
    }
    return rows, report


# Row-only wrappers for the run.py harness (its contract has no report).
def online_merge_parity():
    return merge_parity()[0]


def online_progressive_refine():
    return rounds_to_target()[0]


def online_warm_store():
    return warm_store_reuse()[0]


def online_refined_anchor():
    return refined_anchor_predicate()[0]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes so CI can keep the entrypoints alive")
    ap.add_argument("--out", default=".",
                    help="directory for BENCH_online.json")
    args = ap.parse_args()

    print("name,us_per_call,derived")
    report = {"smoke": bool(args.smoke)}
    for section, bench in (("merge", merge_parity),
                           ("refine", rounds_to_target),
                           ("warm", warm_store_reuse),
                           ("anchor", refined_anchor_predicate)):
        rows, rep = bench(smoke=args.smoke)
        for name, us, derived in rows:
            print(f"{name},{us:.1f},{derived:.6g}", flush=True)
        report[section] = rep
    path = os.path.join(args.out, "BENCH_online.json")
    with open(path, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"# wrote {path} (warm repeat drew "
          f"{report['warm']['warm_repeat_new_samples']} new samples vs "
          f"{report['warm']['cold_samples']} cold; online refine used "
          f"{report['refine']['oneshot_over_online']:.2f}x fewer samples; "
          f"refined anchors hit the bound with "
          f"{report['anchor']['global_over_refined_samples']:.2f}x fewer "
          f"samples than the global anchor)",
          flush=True)


if __name__ == "__main__":
    main()
