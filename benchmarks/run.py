"""Benchmark harness — one function per paper table/figure plus the
framework-level telemetry/kernel benches.  Prints ``name,us_per_call,derived``
CSV (scaffold contract)."""
from __future__ import annotations

import sys


def main() -> None:
    from . import (multiquery_bench, online_bench, paper_tables,
                   telemetry_bench)

    benches = [
        multiquery_bench.batched_vs_sequential_calculation,
        multiquery_bench.multiquery_shared_pass,
        online_bench.online_merge_parity,
        online_bench.online_progressive_refine,
        online_bench.online_warm_store,
        online_bench.online_refined_anchor,
        paper_tables.table3_leverage_effects,
        paper_tables.table4_accuracy,
        paper_tables.table5_modulation,
        paper_tables.fig6_parameters,
        paper_tables.table6_exponential,
        paper_tables.table7_uniform,
        paper_tables.noniid_blocks,
        paper_tables.realdata_salary,
        paper_tables.efficiency,
        telemetry_bench.telemetry_collective_payload,
        telemetry_bench.telemetry_accuracy_speed,
        telemetry_bench.kernel_bench,
    ]
    print("name,us_per_call,derived")
    failures = 0
    for bench in benches:
        try:
            for name, us, derived in bench():
                print(f"{name},{us:.1f},{derived:.6g}", flush=True)
        except Exception as e:  # keep the harness honest but complete
            failures += 1
            print(f"{bench.__name__}/ERROR,0,{type(e).__name__}",
                  file=sys.stderr, flush=True)
    if failures:
        raise SystemExit(1)


if __name__ == '__main__':
    main()
