"""Benchmark harness — one function per paper table/figure plus the
framework-level telemetry/kernel benches.  Prints ``name,us_per_call,derived``
CSV (scaffold contract)."""
from __future__ import annotations

import os
import sys

# The forced host-device count must be pinned BEFORE anything imports
# jax (jax reads XLA_FLAGS at init): the mesh rows below shard over 8
# virtual devices.  Honors a count the caller already forced.
_FORCE_FLAG = "--xla_force_host_platform_device_count"
if _FORCE_FLAG not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + f" {_FORCE_FLAG}=8").strip()


def _sectioned(module, sections):
    """Adapt a sectioned device-tier bench (``(rows, report)`` pairs,
    smoke sizes) into the harness's flat row generator."""
    def rows():
        out = []
        for name in sections:
            section_rows, _ = getattr(module, name)(smoke=True)
            out.extend(section_rows)
        return out
    rows.__name__ = module.__name__.rsplit(".", 1)[-1]
    return rows


def main() -> None:
    from . import (device_bench, mesh_bench, multiquery_bench, online_bench,
                   paper_tables, pipeline_bench, prune_bench, serve_bench,
                   telemetry_bench)

    benches = [
        multiquery_bench.batched_vs_sequential_calculation,
        multiquery_bench.multiquery_shared_pass,
        online_bench.online_merge_parity,
        online_bench.online_progressive_refine,
        online_bench.online_warm_store,
        online_bench.online_refined_anchor,
        paper_tables.table3_leverage_effects,
        paper_tables.table4_accuracy,
        paper_tables.table5_modulation,
        paper_tables.fig6_parameters,
        paper_tables.table6_exponential,
        paper_tables.table7_uniform,
        paper_tables.noniid_blocks,
        paper_tables.realdata_salary,
        paper_tables.efficiency,
        telemetry_bench.telemetry_collective_payload,
        telemetry_bench.telemetry_accuracy_speed,
        telemetry_bench.kernel_bench,
        _sectioned(device_bench,
                   ("tick_speed", "transfer_counts", "dense_fused")),
        _sectioned(mesh_bench, ("tick_scaling", "transfer_audit")),
        _sectioned(prune_bench,
                   ("sample_savings", "residual_parity", "transfer_audit",
                    "tick_speed")),
        _sectioned(serve_bench, ("traffic_replay", "progressive_stream")),
        _sectioned(pipeline_bench,
                   ("steady_throughput", "x64_parity", "transfer_audit")),
    ]
    print("name,us_per_call,derived")
    failures = 0
    for bench in benches:
        try:
            for name, us, derived in bench():
                print(f"{name},{us:.1f},{derived:.6g}", flush=True)
        except Exception as e:  # keep the harness honest but complete
            failures += 1
            print(f"{bench.__name__}/ERROR,0,{type(e).__name__}",
                  file=sys.stderr, flush=True)
    if failures:
        raise SystemExit(1)


if __name__ == '__main__':
    main()
