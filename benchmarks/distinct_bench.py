"""COUNT DISTINCT sketch-plane benchmark: accuracy, parity, overhead.

Headlines (recorded in ``BENCH_distinct.json``):
 * **accuracy** — HLL COUNT DISTINCT relative error vs exact cardinality
   across >= 16 groups spanning both estimator regimes (linear counting
   and the raw harmonic estimate), asserted within the standard
   ~1.04/sqrt(m) error at m = 2^12 with slack;
 * **merge parity** — the same stream ingested as ONE pass and as a
   random partition into ticks yields byte-identical register planes
   (merge = elementwise max is order- and partition-invariant), and the
   device tick's resident plane matches the host plane bit for bit
   (registers key on raw float64 bits, so fp32 pane math never touches
   them);
 * **tick overhead** — the fused device tick with the register pane
   riding the launch vs the moments-only tick at the same size (the
   price of the sketch plane on the steady serving path).

Contract: rows print as ``(name, us_per_call, derived)``; ``--smoke``
shrinks sizes for CI; ``--out DIR`` picks where BENCH_distinct.json
lands.
"""
from __future__ import annotations

import argparse
import json
import os

import numpy as np

from repro.core import sketch as SK
from repro.core.boundaries import make_boundaries
from repro.core.moment_store import MomentStore
from repro.core.types import IslaParams

try:
    from ._timing import time_best
except ImportError:        # script mode: python benchmarks/distinct_bench.py
    from _timing import time_best

MU, SIGMA = 100.0, 20.0
SLACK = 5.0                # tolerance = SLACK * 1.04/sqrt(m): ~5 sigma of
                           # the sketch's standard error, loose enough to
                           # never flake, tight enough to catch a broken
                           # estimator or hash by an order of magnitude


def _grouped_stream(rng, n_groups, n_blocks, rows_per_cell, smoke):
    """A measure stream with KNOWN per-group cardinality: group g draws
    integers from its own disjoint value range whose width sweeps the
    estimator's regimes — small groups sit in linear counting, large
    ones in the raw harmonic-mean estimate."""
    lo, hi = (60, 3000) if smoke else (200, 30000)
    card = np.linspace(lo, hi, n_groups).astype(np.int64)
    vals, gids, bids = [], [], []
    for g in range(n_groups):
        v = rng.integers(0, card[g], size=n_blocks * rows_per_cell)
        vals.append(g * 10 ** 6 + v)          # disjoint per-group ranges
        gids.append(np.full(v.size, g))
        bids.append(np.tile(np.arange(n_blocks), rows_per_cell))
    vals = np.concatenate(vals).astype(np.float64)
    gids = np.concatenate(gids)
    bids = np.concatenate(bids)
    order = rng.permutation(vals.size)
    return vals[order], gids[order], bids[order]


def accuracy(smoke=False):
    """Per-group estimates vs exact cardinality at >= 16 groups, plus
    the partition-merge bit-identity the sketch plane is built on."""
    params = IslaParams()
    b = make_boundaries(MU, SIGMA, params)
    n_groups, n_blocks, rows = (16, 4, 300) if smoke else (24, 8, 1200)
    rng = np.random.default_rng(0)
    vals, gids, bids = _grouped_stream(rng, n_groups, n_blocks, rows,
                                       smoke)
    quotas = np.full(n_blocks, vals.size, dtype=np.int64)

    one = MomentStore.fresh(n_blocks, b, MU, n_groups=n_groups,
                            has_sketch=True)
    one.ingest(vals, bids, quotas, group_ids=gids)

    # The same stream as a RANDOM partition into ticks: registers must
    # fold to the byte-identical plane (merge = max).
    ticks = MomentStore.fresh(n_blocks, b, MU, n_groups=n_groups,
                              has_sketch=True)
    cuts = np.sort(rng.choice(vals.size, size=6, replace=False))
    for seg in np.split(np.arange(vals.size), cuts):
        if seg.size:
            ticks.ingest(vals[seg], bids[seg], quotas,
                         group_ids=gids[seg])
    merge_ok = bool(np.array_equal(one.regs, ticks.regs))
    if not merge_ok:
        raise AssertionError("tick-merged registers != one-pass plane")

    est = one.distinct_counts()
    true = np.array([np.unique(vals[gids == g]).size
                     for g in range(n_groups)], dtype=np.float64)
    rel = np.abs(est - true) / true
    tol = SLACK * SK.REL_ERROR
    if float(rel.max()) > tol:
        raise AssertionError(
            f"distinct error {rel.max():.4f} exceeds {tol:.4f} "
            f"(= {SLACK} x 1.04/sqrt({SK.M}))")
    rows_out = [
        (f"distinct_accuracy/g{n_groups}", 0.0, float(rel.max())),
        ("tick_merge_bit_identical", 0.0, float(merge_ok)),
    ]
    return rows_out, {
        "n_groups": int(n_groups), "m": int(SK.M),
        "true_cardinality_range": [int(true.min()), int(true.max())],
        "max_rel_error": float(rel.max()),
        "mean_rel_error": float(rel.mean()),
        "rel_error_tolerance": float(tol),
        "standard_error": float(SK.REL_ERROR),
        "slack_factor": SLACK,
        "tick_merge_bit_identical": merge_ok,
    }


def route_parity(smoke=False):
    """Host plane vs the device tick's resident plane, bit for bit.

    The device route hashes the SAME raw float64 bits (shipped as uint32
    limb panes) through the in-graph splitmix64 twin, so its uint8
    registers — and therefore every distinct estimate — are
    byte-identical to the host's, even though its moment math runs
    fp32."""
    from repro.core.moment_store import DeviceMomentStore

    params = IslaParams()
    b = make_boundaries(MU, SIGMA, params)
    n_groups, n_blocks, rows = (4, 4, 200) if smoke else (8, 8, 600)
    rng = np.random.default_rng(1)
    vals, gids, bids = _grouped_stream(rng, n_groups, n_blocks, rows,
                                       smoke)
    sizes = np.full(n_blocks, 10.0 ** 6)
    quotas = np.full(n_blocks, vals.size, dtype=np.int64)

    host = MomentStore.fresh(n_blocks, b, MU, n_groups=n_groups,
                             has_sketch=True)
    dev = DeviceMomentStore.fresh_device(n_blocks, b, MU, sizes,
                                         n_groups=n_groups,
                                         has_sketch=True)
    cuts = np.sort(rng.choice(vals.size, size=4, replace=False))
    for seg in np.split(np.arange(vals.size), cuts):
        if not seg.size:
            continue
        host.ingest(vals[seg], bids[seg], quotas, group_ids=gids[seg])
        dev.ingest_tick(vals[seg], bids[seg], quotas, params,
                        group_ids=gids[seg])
    bit = bool(np.array_equal(host.regs, np.asarray(dev.regs)))
    if not bit:
        raise AssertionError("device register plane != host plane")
    est_eq = bool(np.array_equal(host.distinct_counts(),
                                 dev.distinct_counts()))
    rows_out = [("device_plane_bit_identical", 0.0, float(bit))]
    return rows_out, {
        "device_bit_identical": bit,
        "estimates_identical": est_eq,
        "register_bytes_resident": int(np.asarray(dev.regs).nbytes),
    }


def tick_overhead(smoke=False):
    """The steady fused tick with vs without the register pane: same
    draw, same stacked launch shape — the delta is the sketch plane's
    scatter + the O(groups) folded-register readback."""
    from repro.core.moment_store import DeviceMomentStore, DeviceStack

    params = IslaParams()
    b = make_boundaries(MU, SIGMA, params)
    n_groups, n_blocks, quota, rounds = ((3, 16, 40, 3) if smoke
                                         else (8, 200, 64, 8))
    sizes = np.full(n_blocks, 10.0 ** 7)
    rng = np.random.default_rng(2)

    def make_pass():
        vals = rng.normal(MU, SIGMA, n_blocks * quota)
        bids = np.repeat(np.arange(n_blocks), quota)
        gids = rng.integers(0, n_groups, vals.size)
        quotas = np.full(n_blocks, quota, dtype=np.int64)
        return vals, bids, gids, quotas

    passes = [make_pass() for _ in range(rounds + 1)]

    def build(has_sketch):
        stores = [DeviceMomentStore.fresh_device(
            n_blocks, b, MU, sizes, n_groups=n_groups,
            has_sketch=has_sketch)]
        return DeviceStack(stores)

    def tick(stack):
        def f(p):
            vals, bids, gids, quotas = p
            return stack.tick(params, mode="calibrated", values=vals,
                              quotas=quotas, dense=([gids], [None]))
        return f

    plain_best, _ = time_best(tick(build(False)), passes)
    sk_best, _ = time_best(tick(build(True)), passes)
    overhead = sk_best / max(plain_best, 1e-9)
    rows_out = [
        (f"moments_tick/g{n_groups}b{n_blocks}", plain_best, 1.0),
        (f"sketch_tick/g{n_groups}b{n_blocks}", sk_best, overhead),
    ]
    return rows_out, {
        "n_groups": n_groups, "n_blocks": n_blocks,
        "samples_per_tick": int(n_blocks * quota), "rounds": rounds,
        "moments_us_per_tick": plain_best,
        "sketch_us_per_tick": sk_best,
        "overhead_x": overhead,
        "aggregation": "min over rounds",
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes so CI can keep the entrypoints alive")
    ap.add_argument("--out", default=".",
                    help="directory for BENCH_distinct.json")
    args = ap.parse_args()

    print("name,us_per_call,derived")
    report = {"smoke": bool(args.smoke)}
    for section, bench in (("accuracy", accuracy),
                           ("parity", route_parity),
                           ("tick", tick_overhead)):
        rows, rep = bench(smoke=args.smoke)
        for name, us, derived in rows:
            print(f"{name},{us:.1f},{derived:.6g}", flush=True)
        report[section] = rep
    path = os.path.join(args.out, "BENCH_distinct.json")
    with open(path, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
        f.write("\n")
    acc = report["accuracy"]
    print(f"# wrote {path} (max rel error {acc['max_rel_error']:.4f} "
          f"over {acc['n_groups']} groups, tolerance "
          f"{acc['rel_error_tolerance']:.4f}; device plane "
          f"bit-identical: {report['parity']['device_bit_identical']})",
          flush=True)


if __name__ == "__main__":
    main()
