"""Zone-map block pruning benchmark: skip blocks the predicate provably
filters out, end to end through the device tick.

Headlines (recorded in ``BENCH_prune.json``):
 * **sample savings** — a block-clustered 1%-selectivity WHERE answered
   through the executor with a ``ZoneMap`` vs the masked path that
   samples every block and discards non-matching rows: the pruned plan
   rates provably-empty blocks at zero and re-weights Eq. 1 over the
   active mass, so it draws ~1/selectivity fewer rows at the SAME
   (e, beta) — both answers are checked against the ground truth;
 * **residual parity** — the compacted dense launch (gather the active
   block axis, scatter the delta back) against the full-axis launch on
   identical quotas, in float64: the resident moments must come back
   BIT-IDENTICAL on every cell (active cells see the same adds, pruned
   cells are never addressed);
 * **transfer audit** — a steady pruned tick under
   ``jax.transfer_guard("disallow")`` still makes exactly the 4
   sanctioned sample-sized h2d crossings (compact quotas, value pane,
   pad mask, GROUP BY pane): the cached scatter-index pair adds ZERO
   steady-state uploads;
 * **tick speed** — the compacted vs full dense tick at 1% active
   blocks (the pane shrinks ~B/active-fold, so should the launch).

Contract: rows print as ``(name, us_per_call, derived)``; ``--smoke``
shrinks sizes for CI; ``--out DIR`` picks where BENCH_prune.json lands.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from repro.core.boundaries import make_boundaries
from repro.core.engine import IslaQuery
from repro.core.moment_store import DeviceMomentStore
from repro.core.multiquery import MultiQueryExecutor, table_sampler
from repro.core.types import IslaParams, Predicate, ZoneMap

try:
    from ._timing import time_best
except ImportError:          # script mode: python benchmarks/prune_bench.py
    from _timing import time_best

MU, SIGMA = 100.0, 12.0


def _clustered_tables(n_blocks, rows, seed=0):
    """Block-clustered predicate column: block b holds day == b only, so
    ``day == <d>`` matches exactly one block (selectivity 1/n_blocks)."""
    rng = np.random.default_rng(seed)
    tables = []
    for b in range(n_blocks):
        tables.append({
            "value": rng.normal(MU, SIGMA, rows),
            "day": np.full(rows, float(b)),
        })
    return tables


def sample_savings(smoke=False):
    """Executor end-to-end: pruned vs masked at equal (e, beta)."""
    # Block rows sized so the matching population alone supports the
    # target half-width: n_req ~ (z * sigma / e)^2 ~ 2.2k rows.
    n_blocks, rows = (20, 2000) if smoke else (100, 4000)
    tables = _clustered_tables(n_blocks, rows)
    sizes = [rows] * n_blocks
    zm = ZoneMap.from_tables(tables, measure="value")
    q = IslaQuery(e=0.5, beta=0.95, where=Predicate("day", eq=3.0))
    truth = float(np.mean(tables[3]["value"]))

    def run(zone):
        ex = MultiQueryExecutor([table_sampler(t) for t in tables], sizes,
                                zone_map=zm if zone else None)
        t0 = time.perf_counter()
        ans = ex.run([q], np.random.default_rng(7))[0]
        return ans, (time.perf_counter() - t0) * 1e6

    pruned, pruned_us = run(True)
    masked, masked_us = run(False)
    for name, ans in (("pruned", pruned), ("masked", masked)):
        if abs(ans.value - truth) > q.e:
            raise AssertionError(f"{name} answer {ans.value} misses "
                                 f"truth {truth} at e={q.e}")
    savings = masked.new_samples / max(pruned.new_samples, 1)
    if savings <= 5.0:
        raise AssertionError(f"pruning saved only {savings:.2f}x samples "
                             "(need > 5x at 1% selectivity)")
    rows_out = [
        (f"masked_pass/b{n_blocks}", masked_us, float(masked.new_samples)),
        (f"pruned_pass/b{n_blocks}", pruned_us, float(pruned.new_samples)),
    ]
    return rows_out, {
        "n_blocks": n_blocks, "selectivity": 1.0 / n_blocks,
        "masked_samples": int(masked.new_samples),
        "pruned_samples": int(pruned.new_samples),
        "sample_savings_x": savings,
        "truth": truth, "pruned_answer": float(pruned.value),
        "masked_answer": float(masked.value), "e": q.e, "beta": q.beta,
    }


def _stack_pair(n_blocks, n_groups, sizes):
    from repro.core.moment_store import DeviceStack

    params = IslaParams()
    b = make_boundaries(MU, SIGMA, params)
    dstores = [DeviceMomentStore.fresh_device(n_blocks, b, MU, sizes,
                                              n_groups=g)
               for g in (1, n_groups)]
    return DeviceStack(dstores), params


def _pruned_pass(rng, n_blocks, n_groups, active, quota):
    """A zone-pruned pass: only ``active`` blocks draw (ascending block
    order — the ``iter_chunked_draws`` stream contract)."""
    quotas = np.zeros(n_blocks, dtype=np.int64)
    quotas[active] = quota
    vals = rng.normal(MU, SIGMA, active.size * quota)
    gids = rng.integers(0, n_groups, vals.size)
    return vals, gids, quotas


def residual_parity(smoke=False):
    """Compacted vs full dense launch, float64: bit-identical state."""
    import jax

    x64_was = jax.config.jax_enable_x64
    jax.config.update("jax_enable_x64", True)
    try:
        n_blocks, n_groups, quota = (16, 3, 32) if smoke else (128, 8, 64)
        sizes = np.full(n_blocks, 10.0 ** 6)
        active = np.asarray([3, n_blocks - 2])
        outs = []
        for compaction in (True, False):
            rng = np.random.default_rng(5)
            stack, params = _stack_pair(n_blocks, n_groups, sizes)
            stack.block_compaction = compaction
            for _ in range(3):
                vals, gids, quotas = _pruned_pass(rng, n_blocks, n_groups,
                                                  active, quota)
                stack.tick(params, values=vals, quotas=quotas,
                           dense=([None, gids], [None, None]))
            outs.append(tuple(np.asarray(a, dtype=np.float64)
                              for a in stack._state))
        exact = all(np.array_equal(a, b) for a, b in zip(*outs))
        if not exact:
            raise AssertionError("compacted launch is not bit-identical "
                                 "to the full-axis launch in float64")
    finally:
        jax.config.update("jax_enable_x64", x64_was)
    rows = [(f"residual_parity/b{n_blocks}", 0.0, 1.0)]
    return rows, {
        "n_blocks": n_blocks, "active_blocks": [int(a) for a in active],
        "rounds": 3, "dtype": "float64", "bit_identical": True,
    }


def transfer_audit(smoke=False):
    """Steady pruned tick under transfer-guard: 4 sanctioned crossings.

    A single grouped store (the same shape ``device_bench``'s audit
    uses — the multi-store stat-slice path is host-side either way), so
    the guard isolates exactly what pruning adds: nothing."""
    import jax

    from repro.core import distributed as D
    from repro.core.moment_store import DeviceStack

    n_blocks, n_groups, quota = (16, 3, 32) if smoke else (128, 8, 64)
    sizes = np.full(n_blocks, 10.0 ** 6)
    active = np.asarray([3, n_blocks - 2])
    rng = np.random.default_rng(6)
    params = IslaParams()
    b = make_boundaries(MU, SIGMA, params)
    stack = DeviceStack([DeviceMomentStore.fresh_device(
        n_blocks, b, MU, sizes, n_groups=n_groups)])

    def tick():
        vals, gids, quotas = _pruned_pass(rng, n_blocks, n_groups, active,
                                          quota)
        stack.tick(params, values=vals, quotas=quotas,
                   dense=([gids], [None]))

    tick()  # warm-up: compiles, caches the scatter-index pair
    calls = []
    real_h2d = D.h2d

    def counting_h2d(x, dtype=None):
        calls.append(np.asarray(x).nbytes)
        return real_h2d(x, dtype)

    D.h2d = counting_h2d
    try:
        with jax.transfer_guard("disallow"):
            tick()
    finally:
        D.h2d = real_h2d
    if len(calls) != 4:
        raise AssertionError(
            f"steady pruned tick made {len(calls)} h2d crossings, "
            "expected 4 (compact quotas, values, pad mask, group codes)")
    rows = [("steady_pruned_tick_h2d_crossings", 0.0, float(len(calls)))]
    return rows, {
        "sanctioned_h2d_per_tick": len(calls),
        "sanctioned_h2d_bytes": int(sum(calls)),
        "index_pair_h2d_per_steady_tick": 0,
        "transfer_guard": "disallow (sanctioned uploads via h2d only)",
    }


def tick_speed(smoke=False):
    """Compacted vs full-axis dense tick wall time at ~1% active."""
    n_blocks, n_groups, quota, rounds = ((32, 3, 32, 3) if smoke
                                         else (256, 8, 64, 10))
    sizes = np.full(n_blocks, 10.0 ** 6)
    active = np.asarray([3, n_blocks - 2])
    best = {}
    for compaction in (True, False):
        rng = np.random.default_rng(8)
        stack, params = _stack_pair(n_blocks, n_groups, sizes)
        stack.block_compaction = compaction
        # rounds + 1 pre-generated passes: the first warms/compiles
        # (same RNG stream as the old draw-inside-the-loop shape).
        passes = [_pruned_pass(rng, n_blocks, n_groups, active, quota)
                  for _ in range(rounds + 1)]

        def tick_fn(p, stack=stack, params=params):
            vals, gids, quotas = p
            return stack.tick(params, values=vals, quotas=quotas,
                              dense=([None, gids], [None, None]))

        best[compaction], _ = time_best(tick_fn, passes)
    speedup = best[False] / max(best[True], 1e-9)
    rows = [
        (f"full_axis_pruned_tick/b{n_blocks}", best[False], 1.0),
        (f"compacted_pruned_tick/b{n_blocks}", best[True], speedup),
    ]
    return rows, {
        "n_blocks": n_blocks, "active_blocks": int(active.size),
        "full_us_per_tick": best[False],
        "compacted_us_per_tick": best[True],
        "speedup_compacted_vs_full": speedup,
        "aggregation": "min over rounds",
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes so CI can keep the entrypoints alive")
    ap.add_argument("--out", default=".",
                    help="directory for BENCH_prune.json")
    args = ap.parse_args()

    print("name,us_per_call,derived")
    report = {"smoke": bool(args.smoke)}
    for section, bench in (("savings", sample_savings),
                           ("parity", residual_parity),
                           ("transfers", transfer_audit),
                           ("tick", tick_speed)):
        rows, rep = bench(smoke=args.smoke)
        for name, us, derived in rows:
            print(f"{name},{us:.1f},{derived:.6g}", flush=True)
        report[section] = rep
    path = os.path.join(args.out, "BENCH_prune.json")
    with open(path, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"# wrote {path} ({report['savings']['sample_savings_x']:.1f}x "
          f"fewer samples at {report['savings']['selectivity']:.0%} "
          "selectivity; compacted launch bit-identical, "
          f"{report['transfers']['sanctioned_h2d_per_tick']} sanctioned "
          "h2d crossings)", flush=True)


if __name__ == "__main__":
    main()
