"""Shared timing harness for the benchmark suite.

Every bench used to carry its own copy of the same two loops; they live
here once:

 * ``time_best`` — min-over-rounds wall time: warm/compile on the first
   input, then take the MIN over the rest (the usual noisy-shared-host
   estimator of achievable latency).
 * ``time_each`` — per-input wall seconds with untimed per-input setup
   and teardown hooks (traffic-replay style: submit untimed, time the
   tick, drain/assert untimed).

Contract: ``time_best`` reports microseconds (the bench row unit),
``time_each`` reports seconds (percentile math stays in SI).
"""
from __future__ import annotations

import time
from typing import Callable, Optional, Sequence


def time_best(fn: Callable, inputs: Sequence) -> tuple:
    """(best us/call, last output): call ``fn`` once per input, warming
    (and, for jit'd paths, compiling) on ``inputs[0]``, then MIN the
    wall time over ``inputs[1:]``.

    The warm-up call's side effects are kept — persistent-state ticks
    (store merges) stay part of the measured system's history, exactly
    as the per-bench loops behaved."""
    if len(inputs) < 2:
        raise ValueError("time_best needs a warm-up input plus at least "
                         "one timed input")
    fn(inputs[0])
    best, out = float("inf"), None
    for p in inputs[1:]:
        t0 = time.perf_counter()
        out = fn(p)
        best = min(best, (time.perf_counter() - t0) * 1e6)
    return best, out


def time_each(fn: Callable, inputs: Sequence,
              setup: Optional[Callable] = None,
              after: Optional[Callable] = None) -> "list[float]":
    """Per-input wall SECONDS of ``fn(input)``.

    ``setup(input)`` runs untimed before each call (e.g. submit a
    traffic batch); ``after(input, result)`` runs untimed after (e.g.
    drain overflow, assert completion).  No warm-up is skipped — warm
    explicitly before calling when compilation matters."""
    times = []
    for p in inputs:
        if setup is not None:
            setup(p)
        t0 = time.perf_counter()
        r = fn(p)
        times.append(time.perf_counter() - t0)
        if after is not None:
            after(p, r)
    return times
