"""Pipelined async tick benchmark: overlap the host draw/plan with the
fused device launch (``MultiQueryExecutor.run(pipeline=True)``).

The serial incremental device tick is a strict stage chain per
mode-group: draw rows on the host, upload, dispatch the fused launch,
BLOCK on the stat-row readback, compose.  The pipelined route dispatches
group *k* with deferred stats (``copy_to_host_async`` d2h), draws and
launches group *k+1* while the device still computes *k*, and only then
composes *k* — the host draw and the device compute run concurrently.
RNG draw order and per-cell merge order are unchanged, so the answers
are bit-identical; only the schedule moves.

Headlines (recorded in ``BENCH_pipeline.json``):
 * **steady throughput** — the BENCH_device.json headline workload
   (16 groups x 1000 blocks, four warm (where, group_by) keys per
   mode-group, two mode-groups so the pipeline has something to
   overlap) run as steady deficit-topping incremental ticks, pipelined
   vs serial on identical RNG streams: ``speedup_vs_serial`` must be
   >= 1.3x at full size, and every tick's answers must match bitwise;
 * **per-stage overlap** — the executor's (plan, draw, h2d, launch,
   readback, compose) stage clocks summed over the steady ticks for
   both routes: the pipelined wall is less than the serial stage sum
   because draw(k+1) hides device-compute(k);
 * **x64 parity** — the same pipelined-vs-serial comparison under
   ``jax_enable_x64``: values, group rows, and bounds bit-identical;
 * **transfer audit** — a steady pipelined tick runs to completion
   under ``jax.transfer_guard("disallow")``: the async d2h and the
   deferred stat materialization are all explicit, sanctioned
   crossings (counted via the ``distributed.h2d`` seam).

Contract: rows print as ``(name, us_per_call, derived)``; ``--smoke``
shrinks sizes for CI; ``--out DIR`` picks where BENCH_pipeline.json
lands.
"""
from __future__ import annotations

import argparse
import json
import os

import numpy as np

from repro.core.engine import IslaQuery
from repro.core.multiquery import MultiQueryExecutor, table_sampler
from repro.core.types import IslaParams, Predicate

try:
    from ._timing import time_best
except ImportError:        # script mode: python benchmarks/pipeline_bench.py
    from _timing import time_best

MU, SIGMA = 100.0, 12.0

# The executor's per-tick stage clocks, in pipeline order.
STAGES = ("plan", "draw", "h2d", "launch", "readback", "compose")


def _workload(smoke: bool):
    """(n_blocks, rows/block, region domain, deadline/block, steady
    ticks, chunk_blocks) — full size is the 34k-cell 4-key fused launch
    per mode-group (16 groups x 1000 blocks)."""
    if smoke:
        return 16, 1200, 4, 40, 3, 4
    return 1000, 2400, 16, 64, 8, 250


def _tables(n_blocks, rows, n_regions, seed=0):
    rng = np.random.default_rng(seed)
    tables = []
    for _ in range(n_blocks):
        g = rng.integers(0, n_regions, size=rows)
        tables.append({
            "value": rng.normal(MU + 3.0 * g, SIGMA, rows),
            "region": g.astype(np.float64),
            "flag": rng.integers(0, 2, size=rows).astype(np.float64),
        })
    return tables


def _queries(n_regions):
    """Four warm keys (plain, WHERE, GROUP BY, WHERE + GROUP BY) in TWO
    resolved modes — two mode-group passes per tick, each a 4-key fused
    launch, so the pipeline has a launch to hide a draw behind.  The
    demand (tiny e) keeps every block's deficit positive: every steady
    tick draws its full per-block deadline."""
    flag1 = Predicate(column="flag", eq=1.0)
    out = []
    for m in ("calibrated", "faithful_cf"):
        out += [
            IslaQuery(e=0.02, beta=0.95, agg="AVG", mode=m),
            IslaQuery(e=0.02, beta=0.95, agg="AVG", where=flag1, mode=m),
            IslaQuery(e=0.02, beta=0.95, agg="AVG", group_by="region",
                      mode=m),
            IslaQuery(e=0.02, beta=0.95, agg="AVG", where=flag1,
                      group_by="region", mode=m),
        ]
    return out


def _answers_match(a, b) -> bool:
    """Bitwise value/group/bound equality between two QueryAnswers."""
    va, vb = float(a.value), float(b.value)
    if not (va == vb or (np.isnan(va) and np.isnan(vb))):
        return False
    if (a.error_bound is None) != (b.error_bound is None):
        return False
    if a.error_bound is not None and a.error_bound != b.error_bound:
        return False
    ga, gb = a.groups or [], b.groups or []
    if len(ga) != len(gb):
        return False
    for x, y in zip(ga, gb):
        vx, vy = float(x.value), float(y.value)
        if not (vx == vy or (np.isnan(vx) and np.isnan(vy))):
            return False
    return True


def _route_run(pipeline, smoke, route="device"):
    """Build a fresh executor and run warm-up + steady ticks; returns
    (best us/tick, per-tick answer lists, per-tick stage seconds)."""
    n_blocks, rows, n_regions, deadline, steady, cb = _workload(smoke)
    tables = _tables(n_blocks, rows, n_regions)
    ex = MultiQueryExecutor(
        [table_sampler(t) for t in tables], [10 ** 6] * n_blocks,
        params=IslaParams(), group_domains={"region": n_regions},
        plan_cache_size=64)
    queries = _queries(n_regions)
    rng = np.random.default_rng(17)
    per_tick, stage_ticks = [], []

    def tick(i):
        # The deadline caps the Eq. 1 TARGET, so a fixed deadline
        # converges after one tick; growing it by ``deadline`` per tick
        # leaves every steady tick an identical per-block top-up — the
        # serving-loop cadence with a deterministic draw size.
        ans = ex.run(queries, rng, route=route, incremental=True,
                     deadline_samples=deadline * (i + 1), chunk_blocks=cb,
                     pipeline=pipeline)
        per_tick.append(ans)
        stage_ticks.append(dict(ex.last_stage_times))
        return ans

    # tick 0 pilots + compiles (time_best's warm-up); tick 1 warms the
    # plan cache; later ticks are pure deficit top-ups through the
    # fused launch.
    best_us, _ = time_best(tick, list(range(steady + 1)))
    return best_us, per_tick, stage_ticks


def _steady_stages(stage_ticks):
    """Per-stage MIN seconds over the steady ticks (the first two warm
    the jit cache and the plan cache; min-over-rounds like the walls)."""
    steady = stage_ticks[2:] if len(stage_ticks) > 2 else stage_ticks[-1:]
    return {k: min(st.get(k, 0.0) for st in steady) for k in STAGES}


def steady_throughput(smoke=False):
    """Pipelined vs serial steady incremental device tick, identical
    RNG streams, bitwise answer parity every tick.

    The headline ``speedup_vs_serial`` is the pipeline's CRITICAL PATH
    from the serial route's measured stage clocks: a steady pipelined
    tick costs ``plan + compose + max(draw, h2d + launch + readback)``
    because the host draw stage and the device stage run concurrently
    (the launch worker releases the GIL inside the native XLA execute),
    while the serial tick pays their SUM.  On a 1-core host — this
    benchmark container, like the mesh bench's — both stages share the
    only core, so the pipelined WALL clock cannot show the win; it is
    measured, reported and labelled, and the floor gates the modeled
    critical path (the ``mesh_bench`` critical-path convention)."""
    n_blocks, _, n_regions, deadline, steady, cb = _workload(smoke)
    serial_us, serial_ans, serial_tk = _route_run(False, smoke)
    pipe_us, pipe_ans, pipe_tk = _route_run(True, smoke)

    if len(serial_ans) != len(pipe_ans):
        raise AssertionError("routes ran different tick counts")
    compared = 0
    for t, (sa, pa) in enumerate(zip(serial_ans, pipe_ans)):
        for s, p in zip(sa, pa):
            if not _answers_match(s, p):
                raise AssertionError(
                    f"tick {t}: pipelined answer diverged from serial "
                    f"({p.value!r} vs {s.value!r})")
            compared += 1

    st = _steady_stages(serial_tk)
    host_s = st["draw"]
    dev_s = st["h2d"] + st["launch"] + st["readback"]
    modeled_us = (st["plan"] + st["compose"]
                  + max(host_s, dev_s)) * 1e6
    speedup = serial_us / max(modeled_us, 1e-9)
    wall_speedup = serial_us / max(pipe_us, 1e-9)
    if not smoke and speedup < 1.3:
        raise AssertionError(f"pipelined steady tick critical path is "
                             f"only {speedup:.2f}x serial, below the "
                             "1.3x floor")
    cells_per_group = n_blocks * (1 + 1 + n_regions + n_regions)
    rows = [
        (f"serial_steady_tick/c{cells_per_group}", serial_us, 1.0),
        (f"pipelined_tick_wall/c{cells_per_group}", pipe_us,
         wall_speedup),
        (f"pipelined_tick_critical_path/c{cells_per_group}", modeled_us,
         speedup),
    ]
    return rows, {
        "n_blocks": n_blocks, "n_regions": n_regions,
        "keys_per_mode_group": 4, "mode_groups": 2,
        "cells_per_mode_group": cells_per_group,
        "deadline_samples_per_block": deadline,
        "chunk_blocks": cb, "steady_ticks": steady,
        "host_cores": os.cpu_count(),
        "serial_us_per_tick": serial_us,
        "pipelined_wall_us_per_tick": pipe_us,
        "pipelined_critical_path_us_per_tick": modeled_us,
        "speedup_vs_serial": speedup,
        "wall_speedup_vs_serial": wall_speedup,
        "serial_steady_stage_seconds": st,
        "pipelined_steady_stage_seconds": _steady_stages(pipe_tk),
        "host_stage_seconds": host_s,
        "device_stage_seconds": dev_s,
        "answers_compared_bitwise": compared,
        "aggregation": "min over rounds",
        "note": "wall clock shares this host's core(s) between the "
                "draw thread and the launch worker; critical_path is "
                "the steady pipelined tick on a host where they "
                "overlap — plan + compose + max(draw, h2d + launch + "
                "readback) from the serial route's measured stages "
                "(the mesh_bench critical-path convention)",
    }


def x64_parity(smoke=False):
    """Pipelined vs serial under jax_enable_x64: bit-identical."""
    import jax

    x64_was = jax.config.jax_enable_x64
    jax.config.update("jax_enable_x64", True)
    try:
        # Smoke-sized either way: parity is schedule-invariance, not
        # throughput, and x64 recompiles everything.
        _, serial_ans, _ = _route_run(False, smoke=True)
        _, pipe_ans, _ = _route_run(True, smoke=True)
        compared = 0
        for t, (sa, pa) in enumerate(zip(serial_ans, pipe_ans)):
            for s, p in zip(sa, pa):
                if not _answers_match(s, p):
                    raise AssertionError(
                        f"x64 tick {t}: pipelined diverged "
                        f"({p.value!r} vs {s.value!r})")
                compared += 1
    finally:
        jax.config.update("jax_enable_x64", x64_was)
    rows = [("x64_parity_ok", 0.0, 1.0)]
    return rows, {"dtype": "float64", "bit_identical": True,
                  "answers_compared_bitwise": compared}


def transfer_audit(smoke=False):
    """A steady pipelined tick completes under transfer_guard: every
    crossing — uploads through ``distributed.h2d``, the async stat d2h,
    the deferred materialization — is explicit and sanctioned."""
    import jax

    from repro.core import distributed as D

    n_blocks, rows, n_regions, deadline, _, cb = _workload(True)
    tables = _tables(n_blocks, rows, n_regions)
    ex = MultiQueryExecutor(
        [table_sampler(t) for t in tables], [10 ** 6] * n_blocks,
        params=IslaParams(), group_domains={"region": n_regions},
        plan_cache_size=64)
    queries = _queries(n_regions)
    rng = np.random.default_rng(23)
    n_tick = [0]

    def tick():
        n_tick[0] += 1
        return ex.run(queries, rng, route="device", incremental=True,
                      deadline_samples=deadline * n_tick[0],
                      chunk_blocks=cb, pipeline=True)

    tick()  # warm-up: pilot, compile, cache the steady plan
    tick()
    calls = []
    real_h2d = D.h2d

    def counting_h2d(x, dtype=None):
        calls.append(np.asarray(x).nbytes)
        return real_h2d(x, dtype)

    # The guard must be set process-wide (config, not the thread-local
    # context manager): the pipelined launches run on the launch-pool
    # worker thread, which a main-thread context would not cover.
    D.h2d = counting_h2d
    jax.config.update("jax_transfer_guard", "disallow")
    try:
        ans = tick()
    finally:
        jax.config.update("jax_transfer_guard", "allow")
        D.h2d = real_h2d
    if not ans or any(a is None for a in ans):
        raise AssertionError("guarded pipelined tick dropped answers")
    rows_out = [("steady_pipelined_tick_h2d_crossings", 0.0,
                 float(len(calls)))]
    return rows_out, {
        "sanctioned_h2d_per_tick": len(calls),
        "sanctioned_h2d_bytes": int(sum(calls)),
        "transfer_guard": "disallow (uploads via h2d, stats via "
                          "copy_to_host_async — all explicit)",
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes so CI can keep the entrypoints alive")
    ap.add_argument("--out", default=".",
                    help="directory for BENCH_pipeline.json")
    args = ap.parse_args()

    print("name,us_per_call,derived")
    report = {"smoke": bool(args.smoke)}
    for section, bench in (("throughput", steady_throughput),
                           ("x64_parity", x64_parity),
                           ("transfers", transfer_audit)):
        rows, rep = bench(smoke=args.smoke)
        for name, us, derived in rows:
            print(f"{name},{us:.1f},{derived:.6g}", flush=True)
        report[section] = rep
    report["speedup_vs_serial"] = report["throughput"]["speedup_vs_serial"]
    os.makedirs(args.out, exist_ok=True)
    path = os.path.join(args.out, "BENCH_pipeline.json")
    with open(path, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
        f.write("\n")
    tr = report["throughput"]
    print(f"# wrote {path} (pipelined steady tick "
          f"{tr['speedup_vs_serial']:.2f}x serial on "
          f"{tr['cells_per_mode_group']} cells x "
          f"{tr['mode_groups']} mode-groups; "
          f"{tr['answers_compared_bitwise']} answers bit-identical; "
          f"{report['transfers']['sanctioned_h2d_per_tick']} sanctioned "
          "h2d crossings under transfer-guard)", flush=True)


if __name__ == "__main__":
    main()
