"""Online ISLA: progressive refinement of a GROUP BY query from a
persistent moment store (paper §VII-A, served incrementally).

A dashboard keeps re-asking the same GROUP BY question at tightening
precision targets.  With ``incremental=True`` the executor pilots ONCE,
freezes the anchor (boundaries / sketch0 / shift), and keeps a per-
(where, group_by, mode) ``MomentStore``: every round merges its fresh pass
into the store's (group, block) moments — bit-identical to having drawn
one longer stream — so each repeat query draws only the sample DEFICIT its
(e, beta) still demands.  Asking the same question again costs ZERO new
samples; storage stays 8 floats per cell regardless of how many rounds ran.

The second part shows the raw engine view: ``MomentStore.continue_rounds``
refining a plain mean round after round under a fixed per-round budget,
with the ``reanchor`` option re-centering the Phase 2 sketch on the merged
answer.

  PYTHONPATH=src python examples/online_demo.py
"""
import numpy as np

from repro.core import IslaParams, IslaQuery, MomentStore, Predicate
from repro.core.boundaries import make_boundaries
from repro.core.multiquery import MultiQueryExecutor, table_sampler
from repro.core.preestimation import array_sampler

MU, SIGMA = 100.0, 20.0

# ---------------------------------------------------------------------------
# 1. Serving view: one GROUP BY query, refined across four rounds.
# ---------------------------------------------------------------------------

B, G = 100, 6
SIZES = [10 ** 7] * B
rng = np.random.default_rng(3)
tables = []
for _ in range(B):
    g = rng.integers(0, G, size=8192)
    tables.append({
        "value": rng.normal(MU - 12.0 + 4.0 * g, SIGMA),
        "region": g.astype(np.float64),
        "tier": rng.integers(0, 2, size=8192).astype(np.float64),
    })

ex = MultiQueryExecutor([table_sampler(t) for t in tables], SIZES,
                        params=IslaParams(e=1.0),
                        group_domains={"region": G})
qrng = np.random.default_rng(4)

print(f"{B} blocks x {G} groups — GROUP BY AVG refined per round:")
total_new = 0
for e in (2.0, 1.0, 0.5, 0.5):
    (a,) = ex.run([IslaQuery(e=e, agg="AVG", group_by="region",
                             where=Predicate(column="tier", eq=1.0))],
                  qrng, incremental=True)
    total_new += a.new_samples
    bound = f"±{a.error_bound:g}" if a.error_bound is not None \
        else "best-effort"
    cells = ", ".join(f"g{g.group}={g.value:.4g}" for g in a.groups)
    print(f"  e={e:<4} new_samples={a.new_samples:>7} "
          f"(cumulative {a.sample_size:>7})  [{bound}]")
    print(f"        {cells}")
print(f"truth: per-group AVG = 88 + 4*g; the e=0.5 repeat cost "
      f"{a.new_samples} new samples (warm store); "
      f"{total_new} drawn in total\n")

# ---------------------------------------------------------------------------
# 2. Engine view: continue_rounds on a plain store, fixed round budget.
# ---------------------------------------------------------------------------

params = IslaParams(e=0.1)
data_rng = np.random.default_rng(0)
blocks = [data_rng.normal(MU, SIGMA, size=200_000) for _ in range(20)]
samplers = [array_sampler(c) for c in blocks]
sizes = [10 ** 8] * 20

pilot = np.concatenate([c[:500] for c in blocks])
sketch0 = float(np.mean(pilot))
sigma = float(np.std(pilot, ddof=1))
store = MomentStore.fresh(20, make_boundaries(sketch0, sigma, params),
                          sketch0)

print("plain mean, 6 continuation rounds x 2000 samples/block "
      "(reanchor=True):")
rng2 = np.random.default_rng(1)
for round_ in range(1, 7):
    res = store.continue_rounds(samplers, sizes, 2000 / 10 ** 8, params,
                                rng2, mode="calibrated", reanchor=True)
    ans = store.answer(res.avg, sizes)
    print(f"  round {round_}: answer={ans:.4f}  |err|={abs(ans - MU):.4f}  "
          f"samples/block={int(store.n_sampled[0])}  "
          f"sketch0={store.sketch0:.4f}")
print(f"state kept between rounds: {store.mom_s.size + store.mom_l.size} "
      f"floats for {store.total_sampled} samples ever drawn")
