"""Batched serving demo: continuous batching over recycled slots.

  PYTHONPATH=src python examples/serve_demo.py
"""
import sys

sys.path.insert(0, "src")

from repro.launch import serve  # noqa: E402

if __name__ == "__main__":
    sys.argv = [sys.argv[0], "--arch", "olmo-1b", "--reduced",
                "--slots", "4", "--requests", "6", "--max-new", "8",
                "--max-seq", "64"]
    serve.main()
