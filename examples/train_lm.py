"""End-to-end driver: train a ~100M-parameter LM with the full substrate —
sharded step, AdamW, deterministic data, async checkpointing, ISLA loss
telemetry, and a mid-run simulated failure + elastic restart.

Default is a ~100M olmo-family config for 200 steps (hours on this CPU
container); --small runs a ~1M config in ~a minute for CI/demo.

  PYTHONPATH=src python examples/train_lm.py --small
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.configs.base import ArchConfig, register  # noqa: E402
from repro.launch import train as train_driver       # noqa: E402

# ~100M-parameter dense config (olmo-style), registered locally
M100 = ArchConfig(
    name="demo-100m", family="dense", n_layers=12, d_model=768,
    n_heads=12, n_kv_heads=12, d_ff=3072, vocab=32768, head_dim=64,
    norm="rmsnorm", mlp="swiglu", tie_embeddings=True, remat=False,
)
SMALL = M100.replace(name="demo-small", n_layers=4, d_model=128,
                     n_heads=4, n_kv_heads=4, d_ff=512, vocab=2048,
                     head_dim=32)
register(M100, M100.replace(name="demo-100m"))
register(SMALL, SMALL)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--small", action="store_true")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt_demo")
    ap.add_argument("--fail-at", type=int, default=None,
                    help="simulate a failure at this step")
    args = ap.parse_args()

    cfg = SMALL if args.small else M100
    print(f"training {cfg.name}: {cfg.n_params():,} params")
    steps = args.steps or (120 if args.small else 200)
    drv_args = argparse.Namespace(
        arch=cfg.name, reduced=False, steps=steps,
        batch=8 if args.small else 4, seq=128 if args.small else 256,
        lr=3e-3, warmup=20, microbatches=1, model_parallel=1, seed=0,
        ckpt_dir=args.ckpt_dir, ckpt_every=25, resume=True, log_every=10,
        telemetry_exact=True,
        fail=[f"{args.fail_at}:1"] if args.fail_at else None, out=None)
    result = train_driver.run(drv_args)
    hist = result["history"]
    first = sum(h["loss"] for h in hist[:10]) / max(len(hist[:10]), 1)
    last = sum(h["loss"] for h in hist[-10:]) / max(len(hist[-10:]), 1)
    print(f"loss: first10={first:.4f} -> last10={last:.4f}")
    tel = [abs(h.get("loss_mean_isla", 0) - h.get("loss_mean_exact", 0))
           for h in hist if "loss_mean_exact" in h]
    if tel:
        print(f"ISLA telemetry median |err| vs exact: "
              f"{sorted(tel)[len(tel)//2]:.4f}")


if __name__ == "__main__":
    main()
