"""Quickstart: leverage-based approximate AVG aggregation (the paper's core).

Aggregates AVG over a simulated 10^10-row table split into 10 blocks using a
~15k-row sample, and compares against uniform sampling and the measure-biased
baselines (sample+seek).  Runtime: seconds.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import IslaParams, aggregate, baselines
from repro.core.boundaries import make_boundaries
from repro.core.engine import baseline_sample

M = 10 ** 10                       # simulated table size
BLOCKS = 10
SIZES = [M // BLOCKS] * BLOCKS
samplers = [(lambda n, rng: rng.normal(100.0, 20.0, size=n))
            for _ in range(BLOCKS)]   # i.i.d. N(100, 20) per block

params = IslaParams(e=0.1, beta=0.95)       # SELECT AVG(x) ... PRECISION 0.1
rng = np.random.default_rng(0)

result = aggregate(samplers, SIZES, params, rng, mode="auto")
print(f"ISLA answer     : {result.answer:.4f}   (truth 100.0000)")
print(f"  sample size   : {result.sample_size:,} of {M:,} rows "
      f"(rate {result.sampling_rate:.2e})")
print(f"  sketch0/sigma : {result.sketch0:.3f} / {result.sigma:.3f}")
print(f"  block partials: "
      + ", ".join(f"{b.avg - 0:.2f}" for b in result.blocks[:5]) + " ...")

samp = baseline_sample(samplers, SIZES, result.sampling_rate, rng)
bounds = make_boundaries(result.sketch0, result.sigma, params)
print(f"uniform (US)    : {baselines.uniform_avg(samp):.4f}")
print(f"measure-MV      : {baselines.mv_avg(samp):.4f}   (biased to 104)")
print(f"measure-MVB     : {baselines.mvb_avg(samp, bounds):.4f}")
