"""Multi-query ISLA: N concurrent bounded-error aggregates, one sample pass.

A BlinkDB-style dashboard fires AVG / SUM / VAR / COUNT queries with
different precision targets at the same table.  The executor runs ONE pilot
and ONE tagged sampling pass at the strictest rate, then composes every
answer from the shared block moments — the marginal cost of each extra query
is a few float64 array ops.

  PYTHONPATH=src python examples/multiquery_demo.py
"""
import time

import numpy as np

from repro.core import IslaParams, IslaQuery, aggregate
from repro.core.multiquery import MultiQueryExecutor

B = 1000                      # blocks (devices / partitions)
M = 10 ** 10                  # logical rows
SIZES = [M // B] * B
MU, SIGMA = 100.0, 20.0

samplers = [(lambda n, rng, m=MU, s=SIGMA: rng.normal(m, s, size=n))
            for _ in range(B)]

queries = [
    IslaQuery(e=0.1, beta=0.95, agg="AVG"),    # dashboard headline number
    IslaQuery(e=0.2, beta=0.95, agg="SUM"),    # total (bound = M * e)
    IslaQuery(e=0.1, beta=0.99, agg="VAR"),    # spread (best-effort bound)
    IslaQuery(e=0.5, beta=0.95, agg="COUNT"),  # row count (exact)
]

ex = MultiQueryExecutor(samplers, SIZES, params=IslaParams())

ex.run(queries, np.random.default_rng(0))   # warmup (allocator, caches)

t0 = time.perf_counter()
answers = ex.run(queries, np.random.default_rng(0), mode="calibrated")
shared_ms = (time.perf_counter() - t0) * 1e3

print(f"{B} blocks, {len(queries)} concurrent queries, one shared pass "
      f"({shared_ms:.1f} ms total):")
for a in answers:
    bound = "exact" if a.error_bound == 0.0 else (
        f"±{a.error_bound:g} @ beta={a.query.beta}"
        if a.error_bound is not None else "best-effort")
    print(f"  {a.query.agg:>5} = {a.value:>16.4f}   [{bound}]  "
          f"rate={a.sampling_rate:.2e}")

# The naive alternative: one full pipeline per query.
t0 = time.perf_counter()
for q in queries:
    aggregate(samplers, SIZES, IslaParams(e=q.e, beta=q.beta),
              np.random.default_rng(0), mode="calibrated")
naive_ms = (time.perf_counter() - t0) * 1e3
print(f"vs one pipeline per query: {naive_ms:.1f} ms "
      f"({naive_ms / max(shared_ms, 1e-9):.1f}x the work)")

print(f"truth: AVG={MU}, SUM={MU * M:.4g}, VAR={SIGMA ** 2}, COUNT={M:.4g}")
