"""Relational multi-query ISLA: N concurrent bounded-error aggregates with
WHERE + GROUP BY, shared sampling passes.

A BlinkDB-style dashboard fires AVG / SUM / VAR / COUNT queries with
different precision targets at the same table.  The executor runs ONE pilot
per batch and ONE tagged sampling pass per resolved Phase 2 mode-group, then
composes every answer from the shared (group, block) cell moments — the
marginal cost of each extra query is a few float64 array ops, and GROUP BY /
WHERE ride the same vectorized machinery (segment id = group * n_blocks +
block), not a per-group Python loop.

  PYTHONPATH=src python examples/multiquery_demo.py
"""
import time

import numpy as np

from repro.core import IslaParams, IslaQuery, Predicate, aggregate
from repro.core.multiquery import MultiQueryExecutor, table_sampler

B = 1000                      # blocks (devices / partitions)
M = 10 ** 10                  # logical rows
SIZES = [M // B] * B
MU, SIGMA = 100.0, 20.0

# ---------------------------------------------------------------------------
# 1. The flat workload: four aggregates, one shared pass.
# ---------------------------------------------------------------------------

samplers = [(lambda n, rng, m=MU, s=SIGMA: rng.normal(m, s, size=n))
            for _ in range(B)]

queries = [
    IslaQuery(e=0.1, beta=0.95, agg="AVG"),    # dashboard headline number
    IslaQuery(e=0.2, beta=0.95, agg="SUM"),    # total (bound = M * e)
    IslaQuery(e=0.1, beta=0.99, agg="VAR"),    # spread (best-effort bound)
    IslaQuery(e=0.5, beta=0.95, agg="COUNT"),  # row count (exact)
]

ex = MultiQueryExecutor(samplers, SIZES, params=IslaParams())

ex.run(queries, np.random.default_rng(0))   # warmup (allocator, caches)

t0 = time.perf_counter()
answers = ex.run(queries, np.random.default_rng(0), mode="calibrated")
shared_ms = (time.perf_counter() - t0) * 1e3

print(f"{B} blocks, {len(queries)} concurrent queries, one shared pass "
      f"({shared_ms:.1f} ms total):")
for a in answers:
    bound = "exact" if a.error_bound == 0.0 else (
        f"±{a.error_bound:g} @ beta={a.query.beta}"
        if a.error_bound is not None else "best-effort")
    print(f"  {a.query.agg:>5} = {a.value:>16.4f}   [{bound}]  "
          f"rate={a.sampling_rate:.2e}")

# The naive alternative: one full pipeline per query.
t0 = time.perf_counter()
for q in queries:
    aggregate(samplers, SIZES, IslaParams(e=q.e, beta=q.beta),
              np.random.default_rng(0), mode="calibrated")
naive_ms = (time.perf_counter() - t0) * 1e3
print(f"vs one pipeline per query: {naive_ms:.1f} ms "
      f"({naive_ms / max(shared_ms, 1e-9):.1f}x the work)")

print(f"truth: AVG={MU}, SUM={MU * M:.4g}, VAR={SIGMA ** 2}, COUNT={M:.4g}")

# ---------------------------------------------------------------------------
# 2. The relational workload: WHERE + GROUP BY + per-query modes.
# ---------------------------------------------------------------------------

G = 8
RB = 200                      # relational blocks
RSIZES = [10 ** 7] * RB
rng = np.random.default_rng(7)
tables = []
for _ in range(RB):
    g = rng.integers(0, G, size=8192)
    tables.append({
        "value": rng.normal(MU - 10.0 + 2.5 * g, SIGMA),  # group-shifted
        "region": g.astype(np.float64),                   # GROUP BY key
        "tier": rng.integers(0, 2, size=8192).astype(np.float64),
    })

rex = MultiQueryExecutor([table_sampler(t) for t in tables], RSIZES,
                         params=IslaParams(e=0.5),
                         group_domains={"region": G})
rqueries = [
    IslaQuery(e=0.5, agg="AVG", group_by="region"),
    IslaQuery(e=0.5, agg="SUM", group_by="region",
              where=Predicate(column="tier", eq=1.0)),
    IslaQuery(e=0.5, agg="COUNT", where=Predicate(column="value", lo=MU)),
    # per-query mode: this one pins the faithful closed form, so the
    # planner runs it in its own mode-group pass.
    IslaQuery(e=0.5, agg="AVG", mode="faithful_cf"),
]

t0 = time.perf_counter()
ranswers = rex.run(rqueries, np.random.default_rng(1), mode="calibrated")
rel_ms = (time.perf_counter() - t0) * 1e3
n_passes = len({a.pass_id for a in ranswers})
print(f"\n{RB} blocks x {G} groups, {len(rqueries)} relational queries, "
      f"{n_passes} shared passes ({rel_ms:.1f} ms total):")
for a in ranswers:
    sel = a.query.where.describe() if a.query.where else "TRUE"
    gb = a.query.group_by or "-"
    bound = ("exact" if a.error_bound == 0.0 else
             f"±{a.error_bound:g}" if a.error_bound is not None
             else "best-effort")
    print(f"  {a.query.agg:>5} where[{sel}] group_by[{gb}] = "
          f"{a.value:.5g} [{bound}] mode={a.mode} pass={a.pass_id}")
    if a.groups:
        print("        " + ", ".join(
            f"g{g.group}={g.value:.4g}" for g in a.groups))
# match fraction = mean over groups of P(N(90 + 2.5g, 20) >= 100) ~ 0.476
print("truth: per-group AVG = 90 + 2.5*g, COUNT(value>=100) ~ "
      f"{sum(RSIZES) * 0.476:.3g}")
