"""Distributed ISLA telemetry: the paper's engine as a training-metrics
collective, demonstrated over an 8-device host mesh.

Shows: (1) per-device blocks with O(1) moment communication vs an exact
reduction; (2) the collective payload math; (3) int8+error-feedback gradient
compression on the explicit-DP path.

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python examples/approximate_telemetry.py
"""
import os

if "xla_force_host_platform_device_count" not in \
        os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax                                                   # noqa: E402
import jax.numpy as jnp                                      # noqa: E402
import numpy as np                                           # noqa: E402
from jax.sharding import PartitionSpec as P                  # noqa: E402

from repro.compat import shard_map                           # noqa: E402
from repro.core.distributed import exact_mean, isla_mean     # noqa: E402
from repro.core.types import IslaParams                      # noqa: E402
from repro.launch.mesh import make_host_mesh                 # noqa: E402
from repro.train.compression import (dp_allreduce_grads,     # noqa: E402
                                     init_error_feedback)

mesh = make_host_mesh((8,), ("data",))
params = IslaParams(e=0.01)
rng = np.random.default_rng(0)

# fake per-token losses for a (global 512 x 2048)-token step
losses = jnp.asarray(rng.gamma(2.0, 2.0, size=(512, 2048)), jnp.float32)


@jax.jit
def telemetry(x):
    def inner(xs):
        return (isla_mean(xs, params, axis_names=("data",), rate=0.02),
                exact_mean(xs, ("data",)))
    return shard_map(inner, mesh=mesh, in_specs=P("data", None),
                         out_specs=(P(), P()))(x)


isla, exact = telemetry(losses)
print(f"mean per-token loss:  isla={float(isla):.5f}  "
      f"exact={float(exact):.5f}  |err|={abs(float(isla - exact)):.5f}")
per_dev = losses.size // 8
print(f"collective payload:   exact-gather {per_dev * 4:,} B/device  "
      f"vs ISLA {13 * 4} B/device  "
      f"({per_dev * 4 / (13 * 4):,.0f}x less)")
print(f"elements touched:     {losses.size:,} -> "
      f"{int(losses.size * 0.02):,} (rate 0.02)")

# ---- int8 + error-feedback compressed gradient all-reduce
grads = {"w": jnp.asarray(rng.normal(size=(4096,)), jnp.float32)}
ef = init_error_feedback(grads)


@jax.jit
def compressed_dp(g, e):
    def inner(gw, ew):
        out, e2 = dp_allreduce_grads({"w": gw}, {"w": ew}, "data",
                                     compress=True)
        return out["w"], e2["w"]
    return shard_map(inner, mesh=mesh, in_specs=(P(None), P(None)),
                         out_specs=(P(None), P(None)))(g["w"], e["w"])


mean_g, ef_w = compressed_dp(grads, ef)
exact_g = grads["w"]
rel = float(jnp.linalg.norm(mean_g - exact_g) / jnp.linalg.norm(exact_g))
print(f"compressed DP grads:  int8 wire (4x less), rel err {rel:.4f} "
      f"(error-feedback carries the residual)")
